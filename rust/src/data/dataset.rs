//! In-memory datasets, one-pass bounds, binary file I/O and streaming
//! point sources.
//!
//! The sketch is a one-pass statistic, so the coordinator never needs the
//! whole dataset in memory: anything implementing [`PointSource`] can be
//! sketched chunk by chunk (an in-memory dataset, a binary file reader, or
//! a generator that synthesizes points on the fly for the 10⁷-point
//! scaling experiment).

use std::io::{Read, Write};
use std::path::Path;

/// An in-memory dataset: `n_points` rows of dimension `n_dims`, row-major.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub n_dims: usize,
    /// Row-major points, length `n_points * n_dims`.
    pub points: Vec<f64>,
    /// Ground-truth labels when known (synthetic data), else empty.
    pub labels: Vec<usize>,
}

impl Dataset {
    pub fn new(n_dims: usize, points: Vec<f64>) -> Dataset {
        assert!(n_dims > 0 && points.len() % n_dims == 0);
        Dataset { n_dims, points, labels: Vec::new() }
    }

    pub fn n_points(&self) -> usize {
        self.points.len() / self.n_dims
    }

    pub fn point(&self, i: usize) -> &[f64] {
        &self.points[i * self.n_dims..(i + 1) * self.n_dims]
    }

    /// Elementwise bounds `(l, u)` with `l ≤ x_i ≤ u` for all points —
    /// computed in one pass, exactly as the paper prescribes alongside the
    /// sketch (used as box constraints in CLOMPR's gradient steps).
    pub fn bounds(&self) -> Bounds {
        let mut b = Bounds::empty(self.n_dims);
        for i in 0..self.n_points() {
            b.update(self.point(i));
        }
        b
    }

    /// Write as little-endian f64 binary with a 16-byte header.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(&(self.n_points() as u64).to_le_bytes())?;
        f.write_all(&(self.n_dims as u64).to_le_bytes())?;
        for &x in &self.points {
            f.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }

    /// Read back a [`Dataset::save`] file.
    pub fn load(path: &Path) -> anyhow::Result<Dataset> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut h = [0u8; 8];
        f.read_exact(&mut h)?;
        let n_points = u64::from_le_bytes(h) as usize;
        f.read_exact(&mut h)?;
        let n_dims = u64::from_le_bytes(h) as usize;
        anyhow::ensure!(n_dims > 0, "corrupt header: n_dims = 0");
        let mut points = vec![0.0f64; n_points * n_dims];
        let mut buf = [0u8; 8];
        for p in points.iter_mut() {
            f.read_exact(&mut buf)?;
            *p = f64::from_le_bytes(buf);
        }
        Ok(Dataset { n_dims, points, labels: Vec::new() })
    }
}

/// Elementwise box bounds of a point cloud (paper's `l`, `u`).
#[derive(Clone, Debug, PartialEq)]
pub struct Bounds {
    pub lo: Vec<f64>,
    pub hi: Vec<f64>,
}

impl Bounds {
    pub fn empty(n_dims: usize) -> Bounds {
        Bounds { lo: vec![f64::INFINITY; n_dims], hi: vec![f64::NEG_INFINITY; n_dims] }
    }

    pub fn update(&mut self, x: &[f64]) {
        debug_assert_eq!(x.len(), self.lo.len());
        for (i, &v) in x.iter().enumerate() {
            if v < self.lo[i] {
                self.lo[i] = v;
            }
            if v > self.hi[i] {
                self.hi[i] = v;
            }
        }
    }

    pub fn merge(&mut self, other: &Bounds) {
        for i in 0..self.lo.len() {
            self.lo[i] = self.lo[i].min(other.lo[i]);
            self.hi[i] = self.hi[i].max(other.hi[i]);
        }
    }

    /// Whether any point was ever observed.
    pub fn is_valid(&self) -> bool {
        self.lo.iter().zip(&self.hi).all(|(l, h)| l <= h)
    }

    /// Clamp a point into the box, in place.
    pub fn clamp(&self, x: &mut [f64]) {
        for (i, v) in x.iter_mut().enumerate() {
            *v = v.clamp(self.lo[i], self.hi[i]);
        }
    }
}

/// A streaming source of points: fills caller-provided row-major buffers.
///
/// Implementations must be deterministic for a given construction so that
/// sharded (coordinator) and sequential sketching agree in tests.
pub trait PointSource: Send {
    /// Dimension of each point.
    fn n_dims(&self) -> usize;
    /// Total number of points this source will yield.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Fill `buf` (capacity = chunk_rows * n_dims) with the next points;
    /// returns the number of rows written (0 = exhausted).
    fn next_chunk(&mut self, buf: &mut [f64]) -> usize;
}

/// Stream over an in-memory dataset.
pub struct SliceSource<'a> {
    data: &'a [f64],
    n_dims: usize,
    pos: usize,
}

impl<'a> SliceSource<'a> {
    pub fn new(data: &'a [f64], n_dims: usize) -> Self {
        assert!(n_dims > 0 && data.len() % n_dims == 0);
        SliceSource { data, n_dims, pos: 0 }
    }
}

impl<'a> PointSource for SliceSource<'a> {
    fn n_dims(&self) -> usize {
        self.n_dims
    }
    fn len(&self) -> usize {
        self.data.len() / self.n_dims
    }
    fn next_chunk(&mut self, buf: &mut [f64]) -> usize {
        let rows_cap = buf.len() / self.n_dims;
        let remaining = (self.data.len() - self.pos) / self.n_dims;
        let rows = rows_cap.min(remaining);
        let nv = rows * self.n_dims;
        buf[..nv].copy_from_slice(&self.data[self.pos..self.pos + nv]);
        self.pos += nv;
        rows
    }
}

/// A window of at most `limit` rows over another source — lets one
/// long-lived stream be sketched in bounded installments (e.g. one sketch
/// artifact per day of traffic) without rebuilding the underlying source.
///
/// `len()` is an *upper bound*: like [`SliceSource::len`], the inner
/// source reports its construction-time total, so a window over a
/// partially consumed stream may yield fewer rows than `len()` promises.
/// Consumers that need the exact count should drain `next_chunk`.
pub struct TakeSource<'a> {
    inner: &'a mut dyn PointSource,
    remaining: usize,
}

impl<'a> TakeSource<'a> {
    pub fn new(inner: &'a mut dyn PointSource, limit: usize) -> Self {
        TakeSource { inner, remaining: limit }
    }
}

impl<'a> PointSource for TakeSource<'a> {
    fn n_dims(&self) -> usize {
        self.inner.n_dims()
    }
    fn len(&self) -> usize {
        self.remaining.min(self.inner.len())
    }
    fn next_chunk(&mut self, buf: &mut [f64]) -> usize {
        if self.remaining == 0 {
            return 0;
        }
        let n = self.inner.n_dims();
        let rows_cap = (buf.len() / n).min(self.remaining);
        if rows_cap == 0 {
            return 0;
        }
        let rows = self.inner.next_chunk(&mut buf[..rows_cap * n]);
        self.remaining -= rows;
        rows
    }
}

/// A contiguous shard `[start, end)` of a dataset slice, for the
/// coordinator's leader/worker split.
pub struct ShardSource<'a> {
    inner: SliceSource<'a>,
}

impl<'a> ShardSource<'a> {
    pub fn new(data: &'a [f64], n_dims: usize, start: usize, end: usize) -> Self {
        ShardSource { inner: SliceSource::new(&data[start * n_dims..end * n_dims], n_dims) }
    }
}

impl<'a> PointSource for ShardSource<'a> {
    fn n_dims(&self) -> usize {
        self.inner.n_dims()
    }
    fn len(&self) -> usize {
        self.inner.len()
    }
    fn next_chunk(&mut self, buf: &mut [f64]) -> usize {
        self.inner.next_chunk(buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::new(2, vec![0.0, 1.0, -2.0, 5.0, 3.0, -1.0])
    }

    #[test]
    fn dataset_accessors() {
        let d = toy();
        assert_eq!(d.n_points(), 3);
        assert_eq!(d.point(1), &[-2.0, 5.0]);
    }

    #[test]
    fn bounds_one_pass() {
        let b = toy().bounds();
        assert_eq!(b.lo, vec![-2.0, -1.0]);
        assert_eq!(b.hi, vec![3.0, 5.0]);
        assert!(b.is_valid());
        let mut x = vec![10.0, -10.0];
        b.clamp(&mut x);
        assert_eq!(x, vec![3.0, -1.0]);
    }

    #[test]
    fn bounds_merge_equals_whole() {
        let d = toy();
        let mut b1 = Bounds::empty(2);
        b1.update(d.point(0));
        let mut b2 = Bounds::empty(2);
        b2.update(d.point(1));
        b2.update(d.point(2));
        b1.merge(&b2);
        assert_eq!(b1, d.bounds());
    }

    #[test]
    fn save_load_roundtrip() {
        let d = toy();
        let path = std::env::temp_dir().join("ckm_test_ds.bin");
        d.save(&path).unwrap();
        let back = Dataset::load(&path).unwrap();
        assert_eq!(back.n_dims, d.n_dims);
        assert_eq!(back.points, d.points);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn slice_source_chunks_cover() {
        let d = toy();
        let mut src = SliceSource::new(&d.points, 2);
        assert_eq!(src.len(), 3);
        let mut buf = vec![0.0; 4]; // 2 rows per chunk
        let mut collected = Vec::new();
        loop {
            let rows = src.next_chunk(&mut buf);
            if rows == 0 {
                break;
            }
            collected.extend_from_slice(&buf[..rows * 2]);
        }
        assert_eq!(collected, d.points);
    }

    #[test]
    fn take_source_windows_a_stream() {
        let d = toy();
        let mut src = SliceSource::new(&d.points, 2);
        let mut buf = vec![0.0; 64];
        // first window: 2 rows
        let mut w1 = TakeSource::new(&mut src, 2);
        assert_eq!(w1.next_chunk(&mut buf), 2);
        assert_eq!(&buf[..4], &d.points[..4]);
        assert_eq!(w1.next_chunk(&mut buf), 0);
        // second window continues where the first stopped
        let mut w2 = TakeSource::new(&mut src, 5);
        assert_eq!(w2.next_chunk(&mut buf), 1);
        assert_eq!(&buf[..2], &d.points[4..6]);
        assert_eq!(w2.next_chunk(&mut buf), 0);
    }

    #[test]
    fn shards_partition() {
        let d = toy();
        let mut buf = vec![0.0; 64];
        let mut all = Vec::new();
        for (s, e) in [(0usize, 1usize), (1, 3)] {
            let mut sh = ShardSource::new(&d.points, 2, s, e);
            loop {
                let rows = sh.next_chunk(&mut buf);
                if rows == 0 {
                    break;
                }
                all.extend_from_slice(&buf[..rows * 2]);
            }
        }
        assert_eq!(all, d.points);
    }
}
