//! Data layer: in-memory datasets, streaming sources, synthetic generators
//! (paper §4.1 GMM protocol; procedural digits standing in for MNIST —
//! see DESIGN.md §3 for the substitution rationale).

pub mod dataset;
pub mod digits;
pub mod projection;
pub mod gmm;

pub use dataset::{Bounds, Dataset, PointSource, SliceSource};
