//! Synthetic clustered data, following the paper's §4.1 protocol:
//! a mixture of `K` unit-variance Gaussians in dimension `n` with uniform
//! weights, means drawn from `N(0, c·K^{1/n}·Id)` with `c = 1.5` "so that
//! clusters are sufficiently separated with high probability".

use super::dataset::{Dataset, PointSource};
use crate::util::rng::Rng;

/// Configuration for the synthetic Gaussian-mixture generator.
#[derive(Clone, Debug)]
pub struct GmmConfig {
    pub k: usize,
    pub n_dims: usize,
    pub n_points: usize,
    /// Separation constant `c` scaling the means' covariance (paper: 1.5).
    pub separation: f64,
    /// Per-cluster standard deviation (paper: unit Gaussians).
    pub cluster_std: f64,
    /// Mixture weights; `None` = uniform.
    pub weights: Option<Vec<f64>>,
}

impl GmmConfig {
    /// The paper's default artificial-data setup for given sizes.
    pub fn paper_default(k: usize, n_dims: usize, n_points: usize) -> GmmConfig {
        GmmConfig { k, n_dims, n_points, separation: 1.5, cluster_std: 1.0, weights: None }
    }

    /// Draw the mixture means: `μ_k ~ N(0, c·K^{1/n}·Id)`.
    pub fn draw_means(&self, rng: &mut Rng) -> Vec<Vec<f64>> {
        // Covariance c·K^{1/n}·Id → std = sqrt(c·K^{1/n}).
        let std = (self.separation * (self.k as f64).powf(1.0 / self.n_dims as f64)).sqrt();
        (0..self.k)
            .map(|_| (0..self.n_dims).map(|_| rng.normal_with(0.0, std)).collect())
            .collect()
    }

    /// Materialize a full dataset (with ground-truth labels).
    pub fn generate(&self, rng: &mut Rng) -> GmmDataset {
        let means = self.draw_means(rng);
        self.generate_with_means(&means, rng)
    }

    /// Materialize a dataset around externally supplied means — the
    /// drift/replay scenario: shift the same means between epochs and
    /// generate each epoch's batch from the shifted constellation.
    pub fn generate_with_means(&self, means: &[Vec<f64>], rng: &mut Rng) -> GmmDataset {
        assert_eq!(means.len(), self.k, "means count != k");
        assert!(means.iter().all(|m| m.len() == self.n_dims), "mean dims != n_dims");
        let mut points = Vec::with_capacity(self.n_points * self.n_dims);
        let mut labels = Vec::with_capacity(self.n_points);
        let weights = self.normalized_weights();
        for _ in 0..self.n_points {
            let k = sample_component(rng, &weights);
            labels.push(k);
            for d in 0..self.n_dims {
                points.push(means[k][d] + self.cluster_std * rng.normal());
            }
        }
        let mut ds = Dataset::new(self.n_dims, points);
        ds.labels = labels;
        GmmDataset { means: means.to_vec(), dataset: ds }
    }

    /// A deterministic streaming source over the same distribution — the
    /// 10⁷-point scaling experiment sketches this without materializing.
    pub fn stream(&self, seed: u64) -> GmmStream {
        let mut rng = Rng::new(seed);
        let means = self.draw_means(&mut rng);
        GmmStream {
            means,
            cluster_std: self.cluster_std,
            weights: self.normalized_weights(),
            n_dims: self.n_dims,
            remaining: self.n_points,
            total: self.n_points,
            rng,
        }
    }

    fn normalized_weights(&self) -> Vec<f64> {
        match &self.weights {
            None => vec![1.0 / self.k as f64; self.k],
            Some(w) => {
                assert_eq!(w.len(), self.k);
                let s: f64 = w.iter().sum();
                w.iter().map(|x| x / s).collect()
            }
        }
    }
}

fn sample_component(rng: &mut Rng, weights: &[f64]) -> usize {
    rng.categorical(weights).expect("weights sum to 1")
}

/// A generated dataset together with its ground-truth means.
pub struct GmmDataset {
    pub means: Vec<Vec<f64>>,
    pub dataset: Dataset,
}

/// Streaming GMM sampler ([`PointSource`] impl).
pub struct GmmStream {
    pub means: Vec<Vec<f64>>,
    cluster_std: f64,
    weights: Vec<f64>,
    n_dims: usize,
    remaining: usize,
    total: usize,
    rng: Rng,
}

impl PointSource for GmmStream {
    fn n_dims(&self) -> usize {
        self.n_dims
    }
    fn len(&self) -> usize {
        self.total
    }
    fn next_chunk(&mut self, buf: &mut [f64]) -> usize {
        let rows = (buf.len() / self.n_dims).min(self.remaining);
        for r in 0..rows {
            let k = sample_component(&mut self.rng, &self.weights);
            for d in 0..self.n_dims {
                buf[r * self.n_dims + d] = self.means[k][d] + self.cluster_std * self.rng.normal();
            }
        }
        self.remaining -= rows;
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::dist2;

    #[test]
    fn shapes_and_labels() {
        let mut rng = Rng::new(0);
        let g = GmmConfig::paper_default(4, 3, 500).generate(&mut rng);
        assert_eq!(g.dataset.n_points(), 500);
        assert_eq!(g.dataset.n_dims, 3);
        assert_eq!(g.dataset.labels.len(), 500);
        assert!(g.dataset.labels.iter().all(|&l| l < 4));
        assert_eq!(g.means.len(), 4);
    }

    #[test]
    fn points_cluster_near_their_means() {
        let mut rng = Rng::new(1);
        let g = GmmConfig::paper_default(3, 8, 2000).generate(&mut rng);
        // Mean squared distance from a point to its own mean ≈ n (unit
        // Gaussians): E‖x−μ‖² = n = 8.
        let mut acc = 0.0;
        for i in 0..g.dataset.n_points() {
            acc += dist2(g.dataset.point(i), &g.means[g.dataset.labels[i]]);
        }
        let msd = acc / g.dataset.n_points() as f64;
        assert!((msd - 8.0).abs() < 0.8, "msd={msd}");
    }

    #[test]
    fn uniform_weights_balanced() {
        let mut rng = Rng::new(2);
        let g = GmmConfig::paper_default(5, 2, 10_000).generate(&mut rng);
        let mut counts = vec![0usize; 5];
        for &l in &g.dataset.labels {
            counts[l] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 2000.0).abs() < 300.0, "counts={counts:?}");
        }
    }

    #[test]
    fn generate_with_means_plants_the_constellation() {
        let cfg = GmmConfig::paper_default(2, 3, 4000);
        let means = vec![vec![10.0, 0.0, 0.0], vec![-10.0, 0.0, 0.0]];
        let mut rng = Rng::new(7);
        let g = cfg.generate_with_means(&means, &mut rng);
        assert_eq!(g.means, means);
        // every point sits within a few stds of its planted mean
        for i in 0..g.dataset.n_points() {
            let d2 = dist2(g.dataset.point(i), &means[g.dataset.labels[i]]);
            assert!(d2 < 50.0, "point {i} strayed: {d2}");
        }
    }

    #[test]
    fn custom_weights_respected() {
        let mut cfg = GmmConfig::paper_default(2, 2, 20_000);
        cfg.weights = Some(vec![3.0, 1.0]);
        let mut rng = Rng::new(3);
        let g = cfg.generate(&mut rng);
        let c0 = g.dataset.labels.iter().filter(|&&l| l == 0).count();
        assert!((c0 as f64 / 20_000.0 - 0.75).abs() < 0.02);
    }

    #[test]
    fn stream_is_deterministic_and_sized() {
        let cfg = GmmConfig::paper_default(3, 4, 1000);
        let collect = |seed| {
            let mut s = cfg.stream(seed);
            let mut buf = vec![0.0; 128 * 4];
            let mut out = Vec::new();
            loop {
                let rows = s.next_chunk(&mut buf);
                if rows == 0 {
                    break;
                }
                out.extend_from_slice(&buf[..rows * 4]);
            }
            out
        };
        let a = collect(42);
        let b = collect(42);
        let c = collect(43);
        assert_eq!(a.len(), 1000 * 4);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
