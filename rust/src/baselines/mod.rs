//! Baseline clustering algorithms the paper compares against (Lloyd-Max
//! with Range/Sample/K++ seeding) plus mini-batch K-means for the scaling
//! ablation.

pub mod lloyd;
pub mod minibatch;

pub use lloyd::{kmeans, KmInit, KmOptions, KmResult};
pub use minibatch::{minibatch_kmeans, MbOptions};
