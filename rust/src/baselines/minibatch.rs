//! Mini-batch K-means (Sculley 2010) — an additional large-N baseline for
//! the scaling benches: like CKM it avoids full passes per iteration, but
//! unlike CKM it must keep (streaming access to) the data.

use super::lloyd::{assign, kmeanspp_seed, KmResult};
use crate::linalg::matrix::dist2;
use crate::util::rng::Rng;

/// Options for [`minibatch_kmeans`].
#[derive(Clone, Debug)]
pub struct MbOptions {
    pub batch: usize,
    pub iters: usize,
    pub seed: u64,
}

impl Default for MbOptions {
    fn default() -> Self {
        MbOptions { batch: 1024, iters: 100, seed: 0 }
    }
}

/// Mini-batch K-means over row-major points.
pub fn minibatch_kmeans(points: &[f64], n_dims: usize, k: usize, opts: &MbOptions) -> KmResult {
    let n = points.len() / n_dims;
    assert!(k >= 1 && k <= n);
    let mut rng = Rng::new(opts.seed);
    let mut centroids = kmeanspp_seed(points, n_dims, k, &mut rng);
    // Sculley's per-center counts start at zero: the first point assigned
    // to a center gets eta = 1 and *replaces* the k-means++ seed. Seeding
    // the counts at 1 gave every first assignment eta = 1/2, permanently
    // anchoring each centroid halfway to its seed.
    let mut counts = vec![0.0f64; k];
    for _ in 0..opts.iters {
        // Sample a batch and apply per-center running-average updates.
        for _ in 0..opts.batch {
            let i = rng.below(n);
            let x = &points[i * n_dims..(i + 1) * n_dims];
            let mut best = (0usize, f64::INFINITY);
            for c in 0..k {
                let d = dist2(x, centroids.row(c));
                if d < best.1 {
                    best = (c, d);
                }
            }
            let c = best.0;
            counts[c] += 1.0;
            let eta = 1.0 / counts[c];
            let row = centroids.row_mut(c);
            for d in 0..n_dims {
                row[d] += eta * (x[d] - row[d]);
            }
        }
    }
    let mut assignments = vec![0usize; n];
    let sse = assign(points, n_dims, &centroids, &mut assignments);
    KmResult { centroids, assignments, sse, iters: opts.iters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::lloyd::{kmeans, KmOptions};
    use crate::data::gmm::GmmConfig;

    #[test]
    fn close_to_lloyd_on_easy_data() {
        let mut rng = Rng::new(3);
        let mut cfg = GmmConfig::paper_default(4, 4, 4000);
        cfg.separation = 4.0;
        let g = cfg.generate(&mut rng);
        let mb = minibatch_kmeans(&g.dataset.points, 4, 4, &MbOptions::default());
        let km = kmeans(&g.dataset.points, 4, 4, &KmOptions { replicates: 3, ..Default::default() });
        assert!(mb.sse < 2.0 * km.sse, "mb={} lloyd={}", mb.sse, km.sse);
    }

    #[test]
    fn seed_carries_no_residual_weight() {
        // Regression for the counts-start-at-1 bug: with k = 1 every
        // sampled point updates the single center, so the final centroid
        // must be *exactly* the running mean of the sampled points — the
        // k-means++ seed is overwritten by the first assignment (eta = 1),
        // not averaged in at half weight.
        let mut rng = Rng::new(77);
        let g = GmmConfig::paper_default(2, 3, 200).generate(&mut rng);
        let pts = &g.dataset.points;
        let (n, batch) = (200usize, 64usize);
        let opts = MbOptions { batch, iters: 1, seed: 5 };
        let res = minibatch_kmeans(pts, 3, 1, &opts);
        // Replay the identical RNG stream and update arithmetic.
        let mut replay = Rng::new(5);
        let seeds = kmeanspp_seed(pts, 3, 1, &mut replay);
        let mut mean = seeds.row(0).to_vec();
        let mut count = 0.0f64;
        for _ in 0..batch {
            let i = replay.below(n);
            count += 1.0;
            let eta = 1.0 / count;
            for d in 0..3 {
                mean[d] += eta * (pts[i * 3 + d] - mean[d]);
            }
        }
        assert_eq!(res.centroids.row(0), &mean[..]);
    }

    #[test]
    fn deterministic_and_finite() {
        let mut rng = Rng::new(4);
        let g = GmmConfig::paper_default(3, 2, 500).generate(&mut rng);
        let a = minibatch_kmeans(&g.dataset.points, 2, 3, &MbOptions { seed: 8, ..Default::default() });
        let b = minibatch_kmeans(&g.dataset.points, 2, 3, &MbOptions { seed: 8, ..Default::default() });
        assert_eq!(a.centroids.data, b.centroids.data);
        assert!(a.sse.is_finite());
    }
}
