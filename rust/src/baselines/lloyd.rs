//! Lloyd-Max K-means (the paper's `kmeans` baseline) with K-means++ and
//! random seeding, parallel assignment, and empty-cluster repair.
//!
//! The assignment step — the baseline's hot path, and the cost CKM's
//! speed claims are measured against — uses the GEMM formulation
//! `‖x − c‖² = ‖x‖² + ‖c‖² − 2·x·c`: per worker thread, one blocked
//! `X_blk·Cᵀ` product per point block instead of N·K scalar `dist2`
//! loops. The scalar sweep is retained as [`assign_scalar`], the
//! correctness oracle for the parity property tests.

use crate::linalg::matrix::{dist2, dot, matmul_bt_block};
use crate::linalg::Mat;
use crate::util::{parallel, rng::Rng};

/// Seeding rule for Lloyd-Max.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KmInit {
    /// K points uniform in the data's bounding box (paper's "Range").
    Range,
    /// K distinct data points (paper's "Sample").
    Sample,
    /// K-means++ (Arthur & Vassilvitskii 2007; paper's "K++").
    KmeansPp,
}

impl KmInit {
    pub fn parse(s: &str) -> anyhow::Result<KmInit> {
        match s {
            "range" => Ok(KmInit::Range),
            "sample" => Ok(KmInit::Sample),
            "k++" | "kpp" => Ok(KmInit::KmeansPp),
            _ => anyhow::bail!("unknown kmeans init '{s}' (range|sample|k++)"),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            KmInit::Range => "range",
            KmInit::Sample => "sample",
            KmInit::KmeansPp => "k++",
        }
    }
}

/// Options for [`kmeans`].
#[derive(Clone, Debug)]
pub struct KmOptions {
    pub init: KmInit,
    pub max_iters: usize,
    /// Relative SSE improvement below which we stop.
    pub tol: f64,
    pub replicates: usize,
    pub seed: u64,
}

impl Default for KmOptions {
    fn default() -> Self {
        KmOptions { init: KmInit::Range, max_iters: 100, tol: 1e-7, replicates: 1, seed: 0 }
    }
}

/// Result of a K-means run.
#[derive(Clone, Debug)]
pub struct KmResult {
    pub centroids: Mat,
    pub assignments: Vec<usize>,
    pub sse: f64,
    pub iters: usize,
}

/// Lloyd-Max on row-major `points` (`N × n_dims`). Picks the best of
/// `opts.replicates` runs by SSE (the baseline protocol in §4.4).
pub fn kmeans(points: &[f64], n_dims: usize, k: usize, opts: &KmOptions) -> KmResult {
    assert!(n_dims > 0 && points.len() % n_dims == 0);
    let n = points.len() / n_dims;
    assert!(k >= 1 && k <= n, "k={k} out of range for {n} points");
    let mut master = Rng::new(opts.seed);
    let mut best: Option<KmResult> = None;
    for _ in 0..opts.replicates.max(1) {
        let mut rng = master.split();
        let res = lloyd_once(points, n_dims, k, opts, &mut rng);
        if best.as_ref().map(|b| res.sse < b.sse).unwrap_or(true) {
            best = Some(res);
        }
    }
    best.unwrap()
}

fn lloyd_once(points: &[f64], n_dims: usize, k: usize, opts: &KmOptions, rng: &mut Rng) -> KmResult {
    let n = points.len() / n_dims;
    let mut centroids = seed(points, n_dims, k, opts.init, rng);
    let mut assignments = vec![0usize; n];
    let mut sse = f64::INFINITY;
    let mut iters = 0;
    for it in 0..opts.max_iters {
        iters = it + 1;
        // Assignment step (parallel).
        let new_sse = assign(points, n_dims, &centroids, &mut assignments);
        // Update step.
        let mut sums = vec![0.0; k * n_dims];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let a = assignments[i];
            counts[a] += 1;
            for d in 0..n_dims {
                sums[a * n_dims + d] += points[i * n_dims + d];
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Empty cluster: re-seed at the point farthest from its centroid.
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da = dist2(&points[a * n_dims..(a + 1) * n_dims], centroids.row(assignments[a]));
                        let db = dist2(&points[b * n_dims..(b + 1) * n_dims], centroids.row(assignments[b]));
                        da.total_cmp(&db)
                    })
                    .unwrap();
                centroids.row_mut(c).copy_from_slice(&points[far * n_dims..(far + 1) * n_dims]);
            } else {
                for d in 0..n_dims {
                    *centroids.at_mut(c, d) = sums[c * n_dims + d] / counts[c] as f64;
                }
            }
        }
        if (sse - new_sse).abs() <= opts.tol * sse.max(1e-300) {
            sse = new_sse;
            break;
        }
        sse = new_sse;
    }
    // Final consistent assignment + SSE for the returned centroids.
    let final_sse = assign(points, n_dims, &centroids, &mut assignments);
    KmResult { centroids, assignments, sse: final_sse.min(sse), iters }
}

/// Assign each point to its nearest centroid; returns the SSE.
///
/// GEMM formulation: `‖x − c‖² = ‖x‖² + ‖c‖² − 2·x·c`, with the cross
/// terms of each point block computed as one `X_blk·Cᵀ` product per worker
/// thread. Distances are clamped at zero (the expanded form can go a few
/// ulp negative); ties resolve to the lowest centroid index, like
/// [`assign_scalar`].
pub fn assign(points: &[f64], n_dims: usize, centroids: &Mat, out: &mut [usize]) -> f64 {
    let n = points.len() / n_dims;
    assert_eq!(out.len(), n);
    let threads = parallel::default_threads();
    let k = centroids.rows;
    let c_norms: Vec<f64> = (0..k).map(|c| dot(centroids.row(c), centroids.row(c))).collect();
    let c_norms = &c_norms;
    // Rows per X·Cᵀ tile: big enough to amortize the GEMM setup, small
    // enough that the tile (BLOCK × k) stays cache-resident.
    const BLOCK: usize = 128;
    let partials = {
        let ranges = parallel::split_ranges(n, threads);
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            let mut rest: &mut [usize] = out;
            for r in ranges {
                let (head, tail) = rest.split_at_mut(r.len());
                rest = tail;
                handles.push(s.spawn(move || {
                    let mut sse = 0.0;
                    let mut prod = vec![0.0; BLOCK * k];
                    let mut lo = r.start;
                    while lo < r.end {
                        let hi = (lo + BLOCK).min(r.end);
                        let rows = hi - lo;
                        matmul_bt_block(
                            &points[lo * n_dims..hi * n_dims],
                            &centroids.data,
                            &mut prod[..rows * k],
                            0,
                            rows,
                            n_dims,
                            k,
                        );
                        for li in 0..rows {
                            let x = &points[(lo + li) * n_dims..(lo + li + 1) * n_dims];
                            let x_norm = dot(x, x);
                            let xc = &prod[li * k..li * k + k];
                            let mut best = (0usize, f64::INFINITY);
                            for c in 0..k {
                                let d = (x_norm + c_norms[c] - 2.0 * xc[c]).max(0.0);
                                if d < best.1 {
                                    best = (c, d);
                                }
                            }
                            head[lo + li - r.start] = best.0;
                            sse += best.1;
                        }
                        lo = hi;
                    }
                    sse
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
        })
    };
    partials.into_iter().sum()
}

/// Scalar assignment oracle: the direct `dist2` sweep [`assign`] replaces.
/// Kept for parity property tests and before/after benchmarking.
pub fn assign_scalar(points: &[f64], n_dims: usize, centroids: &Mat, out: &mut [usize]) -> f64 {
    let n = points.len() / n_dims;
    assert_eq!(out.len(), n);
    let k = centroids.rows;
    let mut sse = 0.0;
    for i in 0..n {
        let x = &points[i * n_dims..(i + 1) * n_dims];
        let mut best = (0usize, f64::INFINITY);
        for c in 0..k {
            let d = dist2(x, centroids.row(c));
            if d < best.1 {
                best = (c, d);
            }
        }
        out[i] = best.0;
        sse += best.1;
    }
    sse
}

/// Seed `k` centroids.
pub fn seed(points: &[f64], n_dims: usize, k: usize, init: KmInit, rng: &mut Rng) -> Mat {
    let n = points.len() / n_dims;
    match init {
        KmInit::Range => {
            // bounding box
            let mut lo = vec![f64::INFINITY; n_dims];
            let mut hi = vec![f64::NEG_INFINITY; n_dims];
            for i in 0..n {
                for d in 0..n_dims {
                    let v = points[i * n_dims + d];
                    lo[d] = lo[d].min(v);
                    hi[d] = hi[d].max(v);
                }
            }
            Mat::from_fn(k, n_dims, |_, d| rng.uniform_in(lo[d], hi[d].max(lo[d])))
        }
        KmInit::Sample => {
            let idx = rng.sample_indices(n, k);
            let mut c = Mat::zeros(k, n_dims);
            for (r, &i) in idx.iter().enumerate() {
                c.row_mut(r).copy_from_slice(&points[i * n_dims..(i + 1) * n_dims]);
            }
            c
        }
        KmInit::KmeansPp => kmeanspp_seed(points, n_dims, k, rng),
    }
}

/// K-means++ seeding: first center uniform, then ∝ D(x)².
pub fn kmeanspp_seed(points: &[f64], n_dims: usize, k: usize, rng: &mut Rng) -> Mat {
    let n = points.len() / n_dims;
    let mut c = Mat::zeros(k, n_dims);
    let first = rng.below(n);
    c.row_mut(0).copy_from_slice(&points[first * n_dims..(first + 1) * n_dims]);
    let mut d2: Vec<f64> =
        (0..n).map(|i| dist2(&points[i * n_dims..(i + 1) * n_dims], c.row(0))).collect();
    for r in 1..k {
        let pick = rng.categorical(&d2).unwrap_or_else(|| rng.below(n));
        c.row_mut(r).copy_from_slice(&points[pick * n_dims..(pick + 1) * n_dims]);
        for i in 0..n {
            let d = dist2(&points[i * n_dims..(i + 1) * n_dims], c.row(r));
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gmm::GmmConfig;

    #[test]
    fn three_point_exact() {
        // k = n: each point its own cluster, SSE = 0.
        let pts = vec![0.0, 0.0, 5.0, 5.0, -3.0, 4.0];
        let res = kmeans(&pts, 2, 3, &KmOptions { init: KmInit::Sample, ..Default::default() });
        assert!(res.sse < 1e-20, "sse={}", res.sse);
    }

    #[test]
    fn separates_two_blobs() {
        let pts = vec![
            0.0, 0.1, 0.1, -0.1, -0.1, 0.0, // blob A near origin
            10.0, 10.1, 10.1, 9.9, 9.9, 10.0, // blob B near (10,10)
        ];
        let res = kmeans(&pts, 2, 2, &KmOptions { init: KmInit::KmeansPp, seed: 3, ..Default::default() });
        // assignments split 3/3 and first three share a label
        assert_eq!(res.assignments[0], res.assignments[1]);
        assert_eq!(res.assignments[1], res.assignments[2]);
        assert_ne!(res.assignments[0], res.assignments[3]);
        assert!(res.sse < 0.3);
    }

    #[test]
    fn replicates_never_hurt() {
        let mut rng = Rng::new(1);
        let g = GmmConfig::paper_default(5, 4, 2000).generate(&mut rng);
        let one = kmeans(&g.dataset.points, 4, 5, &KmOptions { seed: 7, replicates: 1, ..Default::default() });
        let five = kmeans(&g.dataset.points, 4, 5, &KmOptions { seed: 7, replicates: 5, ..Default::default() });
        assert!(five.sse <= one.sse + 1e-9);
    }

    #[test]
    fn kpp_spreads_seeds() {
        // Two far blobs: k++ almost always picks one seed in each.
        let mut pts = Vec::new();
        for i in 0..50 {
            pts.extend_from_slice(&[i as f64 * 0.01, 0.0]);
        }
        for i in 0..50 {
            pts.extend_from_slice(&[100.0 + i as f64 * 0.01, 0.0]);
        }
        let mut hits = 0;
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let c = kmeanspp_seed(&pts, 2, 2, &mut rng);
            let far = (c.at(0, 0) - c.at(1, 0)).abs() > 50.0;
            hits += usize::from(far);
        }
        assert!(hits >= 19, "k++ split blobs only {hits}/20 times");
    }

    #[test]
    fn sse_decreases_monotonically_enough() {
        let mut rng = Rng::new(2);
        let g = GmmConfig::paper_default(4, 3, 1500).generate(&mut rng);
        let quick = kmeans(&g.dataset.points, 3, 4, &KmOptions { max_iters: 1, seed: 1, ..Default::default() });
        let long = kmeans(&g.dataset.points, 3, 4, &KmOptions { max_iters: 50, seed: 1, ..Default::default() });
        assert!(long.sse <= quick.sse + 1e-9);
    }

    #[test]
    fn assign_consistent_with_sse() {
        let pts = vec![0.0, 1.0, 2.0, 3.0];
        let c = Mat::from_vec(2, 1, vec![0.5, 2.5]);
        let mut a = vec![0usize; 4];
        let sse = assign(&pts, 1, &c, &mut a);
        assert_eq!(a, vec![0, 0, 1, 1]);
        assert!((sse - 4.0 * 0.25).abs() < 1e-12);
    }

    #[test]
    fn prop_gemm_assign_matches_scalar() {
        use crate::testing::{self, gen, Config};
        testing::check("gemm assign == scalar", Config::default().cases(20).max_size(300), |rng, size| {
            let n_dims = 1 + rng.below(8);
            let k = 1 + rng.below(12);
            let n = 1 + size;
            let pts = gen::mat_normal(rng, n, n_dims);
            let c = Mat::from_vec(k, n_dims, gen::mat_normal(rng, k, n_dims));
            let mut a_gemm = vec![0usize; n];
            let mut a_scalar = vec![0usize; n];
            let sse_gemm = assign(&pts, n_dims, &c, &mut a_gemm);
            let sse_scalar = assign_scalar(&pts, n_dims, &c, &mut a_scalar);
            if a_gemm != a_scalar {
                let i = (0..n).find(|&i| a_gemm[i] != a_scalar[i]).unwrap();
                return Err(format!(
                    "assignment mismatch at point {i}: {} vs {}",
                    a_gemm[i], a_scalar[i]
                ));
            }
            testing::close(sse_gemm, sse_scalar, 1e-9)
        });
    }

    #[test]
    fn assign_exact_match_is_zero() {
        // Points identical to centroids: the expanded-form distance must be
        // exactly zero (no negative-epsilon SSE), matching the scalar path.
        let pts = vec![1.5, -2.0, 0.25, 3.0, 0.0, 0.0];
        let c = Mat::from_vec(3, 2, pts.clone());
        let mut a = vec![0usize; 3];
        let sse = assign(&pts, 2, &c, &mut a);
        assert_eq!(a, vec![0, 1, 2]);
        assert_eq!(sse, 0.0);
    }
}
