//! Benchmark harness (criterion substitute): warmup + sampled timing with
//! median/MAD reporting, used by the `rust/benches/*.rs` targets
//! (`harness = false`).

use crate::util::logging::{fmt_duration, Stopwatch};

/// Timing summary over samples.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if s.is_empty() {
            return 0.0;
        }
        s[s.len() / 2]
    }

    /// Median absolute deviation (robust spread).
    pub fn mad(&self) -> f64 {
        let med = self.median();
        let mut dev: Vec<f64> = self.samples.iter().map(|x| (x - med).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if dev.is_empty() {
            0.0
        } else {
            dev[dev.len() / 2]
        }
    }

    pub fn report(&self) {
        println!(
            "bench {:40} median {:>10}  ± {:>9}  ({} samples)",
            self.name,
            fmt_duration(self.median()),
            fmt_duration(self.mad()),
            self.samples.len()
        );
    }
}

/// Time `f` after `warmup` throwaway runs; `samples` measured runs.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let sw = Stopwatch::start();
        f();
        out.push(sw.seconds());
    }
    let m = Measurement { name: name.to_string(), samples: out };
    m.report();
    m
}

/// Throughput helper: items/second at the median.
pub fn throughput(m: &Measurement, items: usize) -> f64 {
    items as f64 / m.median().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_and_mad() {
        let m = Measurement { name: "t".into(), samples: vec![1.0, 2.0, 100.0] };
        assert_eq!(m.median(), 2.0);
        assert_eq!(m.mad(), 1.0);
    }

    #[test]
    fn measure_runs_function() {
        let mut count = 0;
        let m = measure("noop", 2, 3, || count += 1);
        assert_eq!(count, 5);
        assert_eq!(m.samples.len(), 3);
        assert!(throughput(&m, 10) > 0.0);
    }
}
