//! Benchmark harness (criterion substitute): warmup + sampled timing with
//! median/MAD reporting, used by the `rust/benches/*.rs` targets
//! (`harness = false`).
//!
//! [`BenchReport`] collects measurements into machine-readable
//! `BENCH.json` (op name, variant, size, ns/iter, threads) so the perf
//! trajectory of the hot paths is tracked across PRs — see
//! `rust/README.md` § "Reading BENCH.json".

use crate::util::json::Json;
use crate::util::logging::{fmt_duration, Stopwatch};
use std::collections::BTreeMap;

/// Timing summary over samples.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if s.is_empty() {
            return 0.0;
        }
        s[s.len() / 2]
    }

    /// Median absolute deviation (robust spread).
    pub fn mad(&self) -> f64 {
        let med = self.median();
        let mut dev: Vec<f64> = self.samples.iter().map(|x| (x - med).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if dev.is_empty() {
            0.0
        } else {
            dev[dev.len() / 2]
        }
    }

    pub fn report(&self) {
        println!(
            "bench {:40} median {:>10}  ± {:>9}  ({} samples)",
            self.name,
            fmt_duration(self.median()),
            fmt_duration(self.mad()),
            self.samples.len()
        );
    }
}

/// Time `f` after `warmup` throwaway runs; `samples` measured runs.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let sw = Stopwatch::start();
        f();
        out.push(sw.seconds());
    }
    let m = Measurement { name: name.to_string(), samples: out };
    m.report();
    m
}

/// Throughput helper: items/second at the median.
pub fn throughput(m: &Measurement, items: usize) -> f64 {
    items as f64 / m.median().max(1e-12)
}

/// One machine-readable benchmark record (times in nanoseconds).
#[derive(Clone, Debug)]
pub struct BenchRecord {
    /// Operation under test, e.g. `"step5_value_grads"`.
    pub op: String,
    /// Implementation variant, e.g. `"scalar"` / `"batched"` / `"pjrt"`.
    pub variant: String,
    /// Human-readable shape, e.g. `"K=10 m=1000 n=10"`.
    pub size: String,
    pub ns_per_iter: f64,
    pub mad_ns: f64,
    pub samples: usize,
}

/// Collects [`BenchRecord`]s plus derived speedups and serializes them to
/// `BENCH.json`.
#[derive(Default)]
pub struct BenchReport {
    pub records: Vec<BenchRecord>,
    /// Derived `scalar-median / batched-median` ratios keyed by op name.
    pub speedups: BTreeMap<String, f64>,
}

impl BenchReport {
    pub fn new() -> BenchReport {
        BenchReport::default()
    }

    /// Record a measurement under `op`/`variant` with a shape label.
    pub fn add(&mut self, op: &str, variant: &str, size: &str, m: &Measurement) {
        self.records.push(BenchRecord {
            op: op.to_string(),
            variant: variant.to_string(),
            size: size.to_string(),
            ns_per_iter: m.median() * 1e9,
            mad_ns: m.mad() * 1e9,
            samples: m.samples.len(),
        });
    }

    /// Derive `before.median / after.median` for `op` and print it.
    pub fn speedup(&mut self, op: &str, before: &Measurement, after: &Measurement) {
        let s = before.median() / after.median().max(1e-12);
        println!("  -> {op}: {s:.2}x speedup (scalar vs batched)");
        self.speedups.insert(op.to_string(), s);
    }

    pub fn to_json(&self) -> Json {
        let records = self
            .records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("op", Json::Str(r.op.clone())),
                    ("variant", Json::Str(r.variant.clone())),
                    ("size", Json::Str(r.size.clone())),
                    ("ns_per_iter", Json::Num(r.ns_per_iter)),
                    ("mad_ns", Json::Num(r.mad_ns)),
                    ("samples", Json::Num(r.samples as f64)),
                ])
            })
            .collect();
        let speedups =
            self.speedups.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect();
        Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("threads", Json::Num(crate::util::parallel::default_threads() as f64)),
            ("records", Json::Arr(records)),
            ("speedups", Json::Obj(speedups)),
        ])
    }

    /// Write `BENCH.json` (pretty, trailing newline) to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_pretty() + "\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_and_mad() {
        let m = Measurement { name: "t".into(), samples: vec![1.0, 2.0, 100.0] };
        assert_eq!(m.median(), 2.0);
        assert_eq!(m.mad(), 1.0);
    }

    #[test]
    fn measure_runs_function() {
        let mut count = 0;
        let m = measure("noop", 2, 3, || count += 1);
        assert_eq!(count, 5);
        assert_eq!(m.samples.len(), 3);
        assert!(throughput(&m, 10) > 0.0);
    }

    #[test]
    fn report_round_trips_through_json() {
        let slow = Measurement { name: "s".into(), samples: vec![2e-3, 2e-3] };
        let fast = Measurement { name: "f".into(), samples: vec![1e-3, 1e-3] };
        let mut rep = BenchReport::new();
        rep.add("myop", "scalar", "K=2", &slow);
        rep.add("myop", "batched", "K=2", &fast);
        rep.speedup("myop", &slow, &fast);
        let j = rep.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(parsed.get("schema").as_usize(), Some(1));
        assert!(parsed.get("threads").as_usize().unwrap() >= 1);
        let recs = parsed.get("records").as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].get("op").as_str(), Some("myop"));
        assert_eq!(recs[0].get("variant").as_str(), Some("scalar"));
        assert!((recs[0].get("ns_per_iter").as_f64().unwrap() - 2e6).abs() < 1.0);
        let s = parsed.get("speedups").get("myop").as_f64().unwrap();
        assert!((s - 2.0).abs() < 1e-9);
    }
}
