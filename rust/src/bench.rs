//! Benchmark harness (criterion substitute): warmup + sampled timing with
//! median/MAD reporting, used by the `rust/benches/*.rs` targets
//! (`harness = false`).
//!
//! [`BenchReport`] collects measurements into machine-readable
//! `BENCH.json` (op name, variant, size, ns/iter, threads) so the perf
//! trajectory of the hot paths is tracked across PRs — see
//! `rust/README.md` § "Reading BENCH.json".
//!
//! [`diff_reports`] compares two `BENCH.json` files (committed baseline vs
//! a fresh run) and flags `ns_per_iter` regressions beyond a threshold —
//! the comparator behind `ckm bench diff`, wired into the CI bench-smoke
//! job. Baseline records with no timing yet (`ns_per_iter ≤ 0` or
//! `samples = 0` — the committed bootstrap state before the first CI run
//! seeds real numbers) are informational only and never gate.

use crate::util::json::Json;
use crate::util::logging::{fmt_duration, Stopwatch};
use std::collections::BTreeMap;

/// Timing summary over samples.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if s.is_empty() {
            return 0.0;
        }
        s[s.len() / 2]
    }

    /// Median absolute deviation (robust spread).
    pub fn mad(&self) -> f64 {
        let med = self.median();
        let mut dev: Vec<f64> = self.samples.iter().map(|x| (x - med).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if dev.is_empty() {
            0.0
        } else {
            dev[dev.len() / 2]
        }
    }

    pub fn report(&self) {
        println!(
            "bench {:40} median {:>10}  ± {:>9}  ({} samples)",
            self.name,
            fmt_duration(self.median()),
            fmt_duration(self.mad()),
            self.samples.len()
        );
    }
}

/// Time `f` after `warmup` throwaway runs; `samples` measured runs.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let sw = Stopwatch::start();
        f();
        out.push(sw.seconds());
    }
    let m = Measurement { name: name.to_string(), samples: out };
    m.report();
    m
}

/// Throughput helper: items/second at the median.
pub fn throughput(m: &Measurement, items: usize) -> f64 {
    items as f64 / m.median().max(1e-12)
}

/// One machine-readable benchmark record (times in nanoseconds).
#[derive(Clone, Debug)]
pub struct BenchRecord {
    /// Operation under test, e.g. `"step5_value_grads"`.
    pub op: String,
    /// Implementation variant, e.g. `"scalar"` / `"batched"` / `"pjrt"`.
    pub variant: String,
    /// Human-readable shape, e.g. `"K=10 m=1000 n=10"`.
    pub size: String,
    pub ns_per_iter: f64,
    pub mad_ns: f64,
    pub samples: usize,
}

/// Collects [`BenchRecord`]s plus derived speedups and serializes them to
/// `BENCH.json`.
#[derive(Default)]
pub struct BenchReport {
    pub records: Vec<BenchRecord>,
    /// Derived `scalar-median / batched-median` ratios keyed by op name.
    pub speedups: BTreeMap<String, f64>,
}

impl BenchReport {
    pub fn new() -> BenchReport {
        BenchReport::default()
    }

    /// Record a measurement under `op`/`variant` with a shape label.
    pub fn add(&mut self, op: &str, variant: &str, size: &str, m: &Measurement) {
        self.records.push(BenchRecord {
            op: op.to_string(),
            variant: variant.to_string(),
            size: size.to_string(),
            ns_per_iter: m.median() * 1e9,
            mad_ns: m.mad() * 1e9,
            samples: m.samples.len(),
        });
    }

    /// Derive `before.median / after.median` for `op` and print it.
    pub fn speedup(&mut self, op: &str, before: &Measurement, after: &Measurement) {
        let s = before.median() / after.median().max(1e-12);
        println!("  -> {op}: {s:.2}x speedup (baseline vs optimized)");
        self.speedups.insert(op.to_string(), s);
    }

    pub fn to_json(&self) -> Json {
        let records = self
            .records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("op", Json::Str(r.op.clone())),
                    ("variant", Json::Str(r.variant.clone())),
                    ("size", Json::Str(r.size.clone())),
                    ("ns_per_iter", Json::Num(r.ns_per_iter)),
                    ("mad_ns", Json::Num(r.mad_ns)),
                    ("samples", Json::Num(r.samples as f64)),
                ])
            })
            .collect();
        let speedups =
            self.speedups.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect();
        Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("threads", Json::Num(crate::util::parallel::default_threads() as f64)),
            ("records", Json::Arr(records)),
            ("speedups", Json::Obj(speedups)),
        ])
    }

    /// Write `BENCH.json` (pretty, trailing newline) to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_pretty() + "\n")
    }
}

/// One `(op, variant, size)` comparison between two `BENCH.json` reports.
#[derive(Clone, Debug)]
pub struct BenchDelta {
    pub op: String,
    pub variant: String,
    /// The shape label — part of the comparison key, so a baseline timed
    /// at one problem size is never compared against a candidate timed at
    /// another (quick vs full mode would otherwise disarm or false-fire
    /// the regression gate).
    pub size: String,
    pub baseline_ns: f64,
    pub candidate_ns: f64,
    /// `candidate / baseline` — > 1 is slower.
    pub ratio: f64,
}

impl BenchDelta {
    pub fn describe(&self) -> String {
        format!(
            "{}/{} [{}]: {:.0} ns -> {:.0} ns ({:.2}x)",
            self.op, self.variant, self.size, self.baseline_ns, self.candidate_ns, self.ratio
        )
    }
}

/// Result of comparing a candidate `BENCH.json` against a baseline.
#[derive(Clone, Debug, Default)]
pub struct BenchDiff {
    /// Tracked ops slower than `threshold ×` baseline — the CI gate.
    pub regressions: Vec<BenchDelta>,
    /// Tracked ops faster than `baseline / threshold` (informational).
    pub improvements: Vec<BenchDelta>,
    /// Ops compared and within the threshold band.
    pub steady: Vec<BenchDelta>,
    /// Baseline records skipped: bootstrap (no timing yet) or absent from
    /// the candidate run.
    pub skipped: usize,
    /// Candidate records with no baseline counterpart (new ops).
    pub new_ops: Vec<String>,
}

impl BenchDiff {
    pub fn compared(&self) -> usize {
        self.regressions.len() + self.improvements.len() + self.steady.len()
    }
}

type RecordKey = (String, String, String);

fn record_map(report: &Json) -> Result<BTreeMap<RecordKey, f64>, String> {
    let records = report
        .get("records")
        .as_arr()
        .ok_or_else(|| "BENCH.json: missing 'records' array".to_string())?;
    let mut map = BTreeMap::new();
    for r in records {
        let op = r.get("op").as_str().ok_or("record missing 'op'")?.to_string();
        let variant = r.get("variant").as_str().ok_or("record missing 'variant'")?.to_string();
        let size = r.get("size").as_str().unwrap_or("").to_string();
        let ns = r.get("ns_per_iter").as_f64().ok_or("record missing 'ns_per_iter'")?;
        let samples = r.get("samples").as_usize().unwrap_or(0);
        // bootstrap / unmeasured records carry ns <= 0 or no samples
        let ns = if samples == 0 { 0.0 } else { ns };
        map.insert((op, variant, size), ns);
    }
    Ok(map)
}

/// Compare `candidate` against `baseline` (both parsed `BENCH.json`).
/// Records are matched on `(op, variant, size)` — a baseline timed at one
/// problem size never compares against a candidate timed at another (the
/// quick-mode vs full-mode shapes differ by ~5–8×, which would otherwise
/// silently disarm the gate or false-fire it). A tracked op regresses
/// when `candidate_ns > threshold * baseline_ns`; baseline entries
/// without a real timing (bootstrap) never gate.
pub fn diff_reports(baseline: &Json, candidate: &Json, threshold: f64) -> Result<BenchDiff, String> {
    if !(threshold.is_finite() && threshold >= 1.0) {
        return Err(format!("threshold must be >= 1.0, got {threshold}"));
    }
    let base = record_map(baseline)?;
    let cand = record_map(candidate)?;
    let mut diff = BenchDiff::default();
    for ((op, variant, size), &base_ns) in &base {
        let key = (op.clone(), variant.clone(), size.clone());
        match cand.get(&key) {
            Some(&cand_ns) if base_ns > 0.0 && cand_ns > 0.0 => {
                let delta = BenchDelta {
                    op: op.clone(),
                    variant: variant.clone(),
                    size: size.clone(),
                    baseline_ns: base_ns,
                    candidate_ns: cand_ns,
                    ratio: cand_ns / base_ns,
                };
                if delta.ratio > threshold {
                    diff.regressions.push(delta);
                } else if delta.ratio < 1.0 / threshold {
                    diff.improvements.push(delta);
                } else {
                    diff.steady.push(delta);
                }
            }
            _ => diff.skipped += 1,
        }
    }
    for (op, variant, size) in cand.keys() {
        if !base.contains_key(&(op.clone(), variant.clone(), size.clone())) {
            diff.new_ops.push(format!("{op}/{variant} [{size}]"));
        }
    }
    diff.regressions.sort_by(|a, b| b.ratio.total_cmp(&a.ratio));
    diff.improvements.sort_by(|a, b| a.ratio.total_cmp(&b.ratio));
    Ok(diff)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_and_mad() {
        let m = Measurement { name: "t".into(), samples: vec![1.0, 2.0, 100.0] };
        assert_eq!(m.median(), 2.0);
        assert_eq!(m.mad(), 1.0);
    }

    #[test]
    fn measure_runs_function() {
        let mut count = 0;
        let m = measure("noop", 2, 3, || count += 1);
        assert_eq!(count, 5);
        assert_eq!(m.samples.len(), 3);
        assert!(throughput(&m, 10) > 0.0);
    }

    #[test]
    fn diff_flags_regressions_and_skips_bootstrap() {
        let mk = |entries: &[(&str, &str, f64, usize)]| {
            let mut rep = BenchReport::new();
            for (op, variant, ns, samples) in entries {
                rep.records.push(BenchRecord {
                    op: op.to_string(),
                    variant: variant.to_string(),
                    size: "s".into(),
                    ns_per_iter: *ns,
                    mad_ns: 0.0,
                    samples: *samples,
                });
            }
            rep.to_json()
        };
        let base = mk(&[
            ("a", "x", 100.0, 3),
            ("b", "x", 100.0, 3),
            ("boot", "x", 0.0, 0), // committed bootstrap: never gates
            ("gone", "x", 50.0, 3),
        ]);
        let cand =
            mk(&[("a", "x", 200.0, 3), ("b", "x", 40.0, 3), ("boot", "x", 70.0, 3), ("fresh", "x", 10.0, 3)]);
        let diff = diff_reports(&base, &cand, 1.5).unwrap();
        assert_eq!(diff.regressions.len(), 1);
        assert_eq!(diff.regressions[0].op, "a");
        assert!((diff.regressions[0].ratio - 2.0).abs() < 1e-12);
        assert!(diff.regressions[0].describe().contains("2.00x"));
        assert_eq!(diff.improvements.len(), 1);
        assert_eq!(diff.improvements[0].op, "b");
        assert_eq!(diff.skipped, 2); // bootstrap + missing-from-candidate
        assert_eq!(diff.new_ops, vec!["fresh/x [s]".to_string()]);
        assert_eq!(diff.compared(), 2);
        assert!(diff_reports(&base, &cand, 0.5).is_err());

        // size is part of the key: a record re-timed at a different shape
        // is never compared (quick vs full mode must not disarm the gate)
        let resized = {
            let mut rep = BenchReport::new();
            rep.records.push(BenchRecord {
                op: "a".to_string(),
                variant: "x".to_string(),
                size: "other-shape".into(),
                ns_per_iter: 10.0, // would read as a huge 'improvement'
                mad_ns: 0.0,
                samples: 3,
            });
            rep.to_json()
        };
        let d2 = diff_reports(&base, &resized, 1.5).unwrap();
        assert_eq!(d2.compared(), 0);
        assert_eq!(d2.skipped, 4);
        assert_eq!(d2.new_ops, vec!["a/x [other-shape]".to_string()]);

        // everything within the band → steady, nothing gates
        let steady_cand = mk(&[
            ("a", "x", 120.0, 3),
            ("b", "x", 100.0, 3),
            ("gone", "x", 50.0, 3),
            ("boot", "x", 1.0, 3),
        ]);
        let ok = diff_reports(&base, &steady_cand, 1.5).unwrap();
        assert!(ok.regressions.is_empty());
        assert_eq!(ok.steady.len(), 3);
        assert_eq!(ok.skipped, 1);
    }

    #[test]
    fn report_round_trips_through_json() {
        let slow = Measurement { name: "s".into(), samples: vec![2e-3, 2e-3] };
        let fast = Measurement { name: "f".into(), samples: vec![1e-3, 1e-3] };
        let mut rep = BenchReport::new();
        rep.add("myop", "scalar", "K=2", &slow);
        rep.add("myop", "batched", "K=2", &fast);
        rep.speedup("myop", &slow, &fast);
        let j = rep.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(parsed.get("schema").as_usize(), Some(1));
        assert!(parsed.get("threads").as_usize().unwrap() >= 1);
        let recs = parsed.get("records").as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].get("op").as_str(), Some("myop"));
        assert_eq!(recs[0].get("variant").as_str(), Some("scalar"));
        assert!((recs[0].get("ns_per_iter").as_f64().unwrap() - 2e6).abs() < 1.0);
        let s = parsed.get("speedups").get("myop").as_f64().unwrap();
        assert!((s - 2.0).abs() < 1e-9);
    }
}
