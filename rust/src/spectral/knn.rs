//! Exact k-nearest-neighbour graph construction (FLANN substitute).
//!
//! Blocked, multi-threaded brute force: exact at the N this repo runs
//! (the paper uses approximate FLANN at N = 10⁶; our digit pipeline runs
//! at 10³–10⁵ where exact search is fast and removes one approximation).

use crate::linalg::matrix::dist2;
use crate::linalg::sparse::Csr;
use crate::util::parallel;

/// Indices + distances of the k nearest neighbours of each point
/// (excluding the point itself).
pub struct KnnResult {
    pub k: usize,
    /// Row-major (n_points × k) neighbour indices.
    pub indices: Vec<usize>,
    /// Matching squared distances.
    pub dist2: Vec<f64>,
}

/// Exact kNN by blocked brute force, parallel over query ranges.
pub fn knn(points: &[f64], n_dims: usize, k: usize) -> KnnResult {
    assert!(n_dims > 0 && points.len() % n_dims == 0);
    let n = points.len() / n_dims;
    assert!(k >= 1 && k < n, "need 1 <= k < n (k={k}, n={n})");
    let threads = parallel::default_threads();
    let per_query = parallel::parallel_map_ranges(n, threads, |range| {
        let mut out_idx = Vec::with_capacity(range.len() * k);
        let mut out_d2 = Vec::with_capacity(range.len() * k);
        // Max-heap of (d2, idx) capped at k, implemented on a sorted vec
        // (k is small — 10 in the paper).
        let mut heap: Vec<(f64, usize)> = Vec::with_capacity(k + 1);
        for i in range {
            heap.clear();
            let xi = &points[i * n_dims..(i + 1) * n_dims];
            for j in 0..n {
                if j == i {
                    continue;
                }
                let d = dist2(xi, &points[j * n_dims..(j + 1) * n_dims]);
                if heap.len() < k {
                    heap.push((d, j));
                    if heap.len() == k {
                        heap.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                    }
                } else if d < heap[k - 1].0 {
                    // insert in sorted position, drop the tail
                    let pos = heap.partition_point(|e| e.0 < d);
                    heap.insert(pos, (d, j));
                    heap.pop();
                }
            }
            if heap.len() < k {
                heap.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            }
            for &(d, j) in heap.iter() {
                out_idx.push(j);
                out_d2.push(d);
            }
        }
        (out_idx, out_d2)
    });
    let mut indices = Vec::with_capacity(n * k);
    let mut d2 = Vec::with_capacity(n * k);
    for (pi, pd) in per_query {
        indices.extend(pi);
        d2.extend(pd);
    }
    KnnResult { k, indices, dist2: d2 }
}

/// Symmetrized binary kNN adjacency: `A_ij = 1` if `j ∈ kNN(i)` or
/// `i ∈ kNN(j)` (the "K-nearest neighbours adjacency matrix" of §4.1).
pub fn knn_adjacency(points: &[f64], n_dims: usize, k: usize) -> Csr {
    let n = points.len() / n_dims;
    let res = knn(points, n_dims, k);
    let mut t = Vec::with_capacity(2 * n * k);
    for i in 0..n {
        for &j in &res.indices[i * k..(i + 1) * k] {
            t.push((i, j, 1.0));
            t.push((j, i, 1.0));
        }
    }
    let mut a = Csr::from_triplets(n, n, t);
    // OR-semantics: clamp summed duplicates back to 1.
    for v in a.data.iter_mut() {
        *v = 1.0;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{self, gen, Config};
    use crate::util::rng::Rng;

    #[test]
    fn line_graph_neighbours() {
        // points at 0, 1, 2, 10: kNN(k=1) of 0 is 1; of 10 is 2.
        let pts = vec![0.0, 1.0, 2.0, 10.0];
        let r = knn(&pts, 1, 1);
        assert_eq!(r.indices, vec![1, 0, 1, 2]);
        assert_eq!(r.dist2[0], 1.0);
        assert_eq!(r.dist2[3], 64.0);
    }

    #[test]
    fn prop_knn_matches_naive() {
        testing::check("knn == naive", Config::default().cases(16).max_size(30), |rng, size| {
            let n = 4 + size;
            let d = 1 + rng.below(4);
            let k = 1 + rng.below(3.min(n - 2));
            let pts = gen::mat_normal(rng, n, d);
            let res = knn(&pts, d, k);
            for i in 0..n {
                // naive: sort all distances
                let mut all: Vec<(f64, usize)> = (0..n)
                    .filter(|&j| j != i)
                    .map(|j| (crate::linalg::matrix::dist2(&pts[i * d..(i + 1) * d], &pts[j * d..(j + 1) * d]), j))
                    .collect();
                all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                let got: Vec<f64> = res.dist2[i * k..(i + 1) * k].to_vec();
                let want: Vec<f64> = all[..k].iter().map(|e| e.0).collect();
                testing::all_close(&got, &want, 1e-12)
                    .map_err(|e| format!("query {i}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn adjacency_symmetric_binary_no_selfloops() {
        let mut rng = Rng::new(3);
        let pts = gen::mat_normal(&mut rng, 40, 3);
        let a = knn_adjacency(&pts, 3, 5);
        let d = a.to_dense();
        for i in 0..40 {
            assert_eq!(d[i * 40 + i], 0.0, "self loop at {i}");
            for j in 0..40 {
                assert_eq!(d[i * 40 + j], d[j * 40 + i]);
                assert!(d[i * 40 + j] == 0.0 || d[i * 40 + j] == 1.0);
            }
        }
        // every vertex has degree >= k
        for i in 0..40 {
            let deg: f64 = (0..40).map(|j| d[i * 40 + j]).sum();
            assert!(deg >= 5.0);
        }
    }
}
