//! Spectral clustering substrate (the paper's MNIST pipeline): exact kNN
//! graph, normalized Laplacian, Lanczos eigenvectors, NJW embedding.

pub mod cluster;
pub mod knn;

pub use cluster::{spectral_embed, SpectralConfig};
pub use knn::{knn, knn_adjacency};
