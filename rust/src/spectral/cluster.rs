//! Spectral embedding + clustering pipeline (paper §4.1, MNIST protocol):
//! kNN graph → symmetric normalized Laplacian → first `K` Laplacian
//! eigenvectors (Lanczos) → row-normalized spectral features → K-means
//! (Lloyd-Max or CKM) on the features.

use super::knn::knn_adjacency;
use crate::linalg::eigen::csr_smallest_eigenpairs;
use crate::linalg::sparse::normalized_laplacian;

/// Configuration of the spectral embedding.
#[derive(Clone, Debug)]
pub struct SpectralConfig {
    /// Neighbours in the kNN graph (paper: 10).
    pub knn_k: usize,
    /// Embedding dimension = number of Laplacian eigenvectors (paper: 10).
    pub embed_dim: usize,
    /// Lanczos Krylov budget (0 = auto).
    pub lanczos_dim: usize,
    pub seed: u64,
}

impl Default for SpectralConfig {
    fn default() -> Self {
        SpectralConfig { knn_k: 10, embed_dim: 10, lanczos_dim: 0, seed: 0x5EC7 }
    }
}

/// Row-major (n_points × embed_dim) spectral features (NJW row-normalized).
pub fn spectral_embed(points: &[f64], n_dims: usize, cfg: &SpectralConfig) -> Vec<f64> {
    let n = points.len() / n_dims;
    assert!(n > cfg.knn_k, "need more points than knn_k");
    let adj = knn_adjacency(points, n_dims, cfg.knn_k);
    let lap = normalized_laplacian(&adj);
    let pairs = csr_smallest_eigenpairs(&lap, cfg.embed_dim, cfg.seed);
    let d = pairs.vectors.len();
    let mut feats = vec![0.0; n * d];
    for (j, v) in pairs.vectors.iter().enumerate() {
        for i in 0..n {
            feats[i * d + j] = v[i];
        }
    }
    // NJW row normalization (unit rows; zero rows left as-is).
    for i in 0..n {
        let row = &mut feats[i * d..(i + 1) * d];
        let norm: f64 = row.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm > 1e-12 {
            for x in row.iter_mut() {
                *x /= norm;
            }
        }
    }
    feats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{kmeans, KmInit, KmOptions};
    use crate::metrics::adjusted_rand_index;
    use crate::util::rng::Rng;

    /// Three well-separated 2-d blobs.
    fn blobs(n_per: usize, rng: &mut Rng) -> (Vec<f64>, Vec<usize>) {
        let centers = [(0.0, 0.0), (8.0, 0.0), (0.0, 8.0)];
        let mut pts = Vec::new();
        let mut labels = Vec::new();
        for (ci, &(cx, cy)) in centers.iter().enumerate() {
            for _ in 0..n_per {
                pts.push(cx + 0.5 * rng.normal());
                pts.push(cy + 0.5 * rng.normal());
                labels.push(ci);
            }
        }
        (pts, labels)
    }

    #[test]
    fn embeds_blobs_into_separable_features() {
        let mut rng = Rng::new(1);
        let (pts, labels) = blobs(50, &mut rng);
        let cfg = SpectralConfig { knn_k: 8, embed_dim: 3, lanczos_dim: 0, seed: 2 };
        let feats = spectral_embed(&pts, 2, &cfg);
        assert_eq!(feats.len(), 150 * 3);
        // K-means on the embedding must nail the blobs.
        let km = kmeans(&feats, 3, 3, &KmOptions { init: KmInit::KmeansPp, replicates: 3, seed: 3, ..Default::default() });
        let ari = adjusted_rand_index(&km.assignments, &labels);
        assert!(ari > 0.98, "ari={ari}");
    }

    #[test]
    fn rows_are_unit_norm() {
        let mut rng = Rng::new(4);
        let (pts, _) = blobs(30, &mut rng);
        let cfg = SpectralConfig { knn_k: 5, embed_dim: 3, lanczos_dim: 0, seed: 5 };
        let feats = spectral_embed(&pts, 2, &cfg);
        for i in 0..90 {
            let norm: f64 = feats[i * 3..(i + 1) * 3].iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-9, "row {i} norm {norm}");
        }
    }
}
