//! Key-sharded sketch stores: N independent [`SketchStore`]s behind N
//! independent locks, with exact cross-shard merged snapshots.
//!
//! The `ckmd` daemon assigns every producer to one shard by hashing its
//! producer id (FNV-1a mod `n_shards`), so producers on different shards
//! never contend on one mutex — reserve/absorb critical sections stay
//! per-shard. Each shard salts its quantized dither stream with
//! `base_shard + shard_index` (exactly the facade's
//! [`crate::api::CkmBuilder::shard`] semantics), which keeps every
//! shard's integer state independently bit-reproducible. Cross-shard
//! snapshots are *exact* because the sketch algebra is associative: a
//! merged window is the artifact-level merge of the per-shard windows
//! (integer adds for quantized rings), and a merged decayed snapshot
//! pools the per-shard λ-weighted partials and scales once — identical
//! weighting to a single pooled ring, provided shards rotate in lockstep
//! (which [`ShardedStore::rotate_all`] guarantees).

use super::ring::{ChunkSketch, CompactionPolicy, EpochStats, SketchContext, SketchStore};
use crate::api::{ApiError, OpSpec, QuantizationMode, SketchArtifact};
use crate::data::dataset::Bounds;
use crate::linalg::CVec;
use crate::util::digest::Fnv1a;
use crate::util::json::Json;
use crate::util::sync::lock_recover;
use std::path::Path;
use std::sync::{Mutex, MutexGuard};

/// Version of the `ckm-store-set` JSON schema.
pub const STORE_SET_FORMAT_VERSION: u32 = 1;

/// Per-shard introspection record (see [`ShardedStore::shard_stats`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ShardStats {
    /// Shard index within the set (0-based).
    pub shard: usize,
    /// Store-lifetime rows (includes evicted epochs).
    pub rows_ingested: usize,
    /// Rows across surviving epochs.
    pub surviving_rows: usize,
    /// Surviving epoch buckets.
    pub epochs: usize,
    /// Shard mutation counter.
    pub generation: u64,
    pub current_epoch_id: u64,
}

/// N key-sharded [`SketchStore`]s with uniform provenance.
#[derive(Debug)]
pub struct ShardedStore {
    shards: Vec<Mutex<SketchStore>>,
    spec: OpSpec,
    quantization: Option<QuantizationMode>,
    base_shard: u64,
}

impl ShardedStore {
    /// Build `n_shards` stores sharing one operator spec; shard `i` salts
    /// its dither stream with `base_shard + i`.
    pub fn create(
        spec: OpSpec,
        quantization: Option<QuantizationMode>,
        base_shard: u64,
        n_shards: usize,
        capacity: Option<usize>,
        compaction: CompactionPolicy,
    ) -> Result<ShardedStore, ApiError> {
        if n_shards == 0 {
            return Err(ApiError::InvalidConfig {
                field: "shards",
                reason: "need at least one shard".into(),
            });
        }
        let mut shards = Vec::with_capacity(n_shards);
        for i in 0..n_shards {
            let store =
                SketchStore::create(spec.clone(), quantization, base_shard + i as u64, capacity)?
                    .with_compaction(compaction);
            shards.push(Mutex::new(store));
        }
        Ok(ShardedStore {
            shards,
            spec,
            quantization: quantization.map(QuantizationMode::normalized),
            base_shard,
        })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn spec(&self) -> &OpSpec {
        &self.spec
    }

    pub fn quantization(&self) -> Option<QuantizationMode> {
        self.quantization
    }

    pub fn base_shard(&self) -> u64 {
        self.base_shard
    }

    /// The deterministic producer→shard assignment: FNV-1a of the
    /// producer id, mod the shard count.
    pub fn shard_for_producer(&self, producer: &str) -> usize {
        (Fnv1a::hash(producer.as_bytes()) % self.shards.len() as u64) as usize
    }

    /// Lock one shard, recovering from poison: shard mutations are
    /// validate-then-write (a panicking absorber bails before touching
    /// the ring), so a poisoned guard still protects consistent state —
    /// see [`crate::util::sync`].
    fn shard(&self, idx: usize) -> MutexGuard<'_, SketchStore> {
        lock_recover(&self.shards[idx])
    }

    /// The immutable phase-2 sketch context for one shard (operator,
    /// quantization, that shard's dither seed).
    pub fn context(&self, shard: usize) -> SketchContext {
        self.shard(shard).sketch_context()
    }

    /// That shard's dither-stream seed.
    pub fn dither_seed(&self, shard: usize) -> u64 {
        self.shard(shard).dither_seed()
    }

    /// Phase 1: reserve `n_rows` global row indices on one shard.
    pub fn reserve(&self, shard: usize, n_rows: usize) -> usize {
        self.shard(shard).reserve_rows(n_rows)
    }

    /// Phase 3: validate and exactly merge an outside-sketched chunk into
    /// one shard's current epoch. Unlike [`SketchStore::absorb`] this
    /// never panics: a chunk that disagrees with the shard's provenance
    /// (wrong kind, mode, shape, or dither stream — i.e. anything an
    /// untrusted network peer could ship) is rejected with a typed error
    /// and the store is left untouched.
    pub fn try_absorb(&self, shard: usize, chunk: ChunkSketch) -> Result<usize, ApiError> {
        let err = |msg: String| Err(ApiError::ServiceProtocol(format!("absorb: {msg}")));
        let m = self.spec.m;
        let n = self.spec.n_dims;
        match (&chunk, self.quantization) {
            (ChunkSketch::Dense(_), Some(_)) => {
                return err("dense chunk for a quantized store".into())
            }
            (ChunkSketch::Quantized(_), None) => {
                return err("quantized chunk for a dense store".into())
            }
            (ChunkSketch::Dense(a), None) => {
                if a.sum.len() != m {
                    return err(format!("chunk m = {} != store m = {m}", a.sum.len()));
                }
                if a.bounds.lo.len() != n {
                    return err(format!(
                        "chunk bounds dims = {} != store dims = {n}",
                        a.bounds.lo.len()
                    ));
                }
                let finite =
                    a.sum.re.iter().chain(&a.sum.im).all(|v| v.is_finite());
                if !finite {
                    return err("non-finite sketch sum".into());
                }
                if a.count > 0 && !a.bounds.is_valid() {
                    return err("chunk carries rows but empty/invalid bounds".into());
                }
            }
            (ChunkSketch::Quantized(a), Some(mode)) => {
                if a.mode != mode {
                    return err(format!(
                        "chunk quantization {} != store {}",
                        a.mode.name(),
                        mode.name()
                    ));
                }
                if a.m() != m {
                    return err(format!("chunk m = {} != store m = {m}", a.m()));
                }
                if a.bounds.lo.len() != n {
                    return err(format!(
                        "chunk bounds dims = {} != store dims = {n}",
                        a.bounds.lo.len()
                    ));
                }
                if a.count > 0 && !a.bounds.is_valid() {
                    return err("chunk carries rows but empty/invalid bounds".into());
                }
                let max = a.count as u64 * (a.mode.levels() - 1);
                if a.level_sums.iter().any(|&v| v > max) {
                    return err(format!("level sum exceeds count·(levels−1) = {max}"));
                }
                let store = self.shard(shard);
                if a.dither_seed != store.dither_seed() {
                    return err(format!(
                        "chunk dither seed {:#x} != shard seed {:#x}",
                        a.dither_seed,
                        store.dither_seed()
                    ));
                }
                drop(store);
            }
        }
        Ok(self.shard(shard).absorb(chunk))
    }

    /// Synchronous single-lock ingest into one shard (loopback/test path).
    pub fn ingest(&self, shard: usize, rows: &[f64]) -> usize {
        self.shard(shard).ingest(rows)
    }

    /// Rotate every shard (lockstep time). Returns `(shard, evicted ids)`
    /// per shard that evicted anything.
    pub fn rotate_all(&self) -> Vec<(usize, Vec<u64>)> {
        let mut out = Vec::new();
        for (i, s) in self.shards.iter().enumerate() {
            let evicted = lock_recover(s).rotate();
            if !evicted.is_empty() {
                out.push((i, evicted));
            }
        }
        out
    }

    /// Current per-shard generations, sampled under all shard locks (a
    /// consistent cut — the vector a merged snapshot is keyed by).
    pub fn generations(&self) -> Vec<u64> {
        let guards = self.lock_all();
        guards.iter().map(|g| g.generation()).collect()
    }

    /// Lock every shard in index order (the only multi-lock path, so the
    /// fixed order makes deadlock impossible).
    fn lock_all(&self) -> Vec<MutexGuard<'_, SketchStore>> {
        self.shards.iter().map(lock_recover).collect()
    }

    /// Exact cross-shard window merge: each shard's `window(last_e)`
    /// (`None` = everything surviving), merged at the artifact level.
    /// Snapshotted under all shard locks, merged after they drop; returns
    /// the artifact plus the generation vector it corresponds to.
    pub fn merged_window(
        &self,
        last_e: Option<usize>,
    ) -> Result<(SketchArtifact, Vec<u64>), ApiError> {
        let (parts, gens) = {
            let guards = self.lock_all();
            let mut parts = Vec::with_capacity(guards.len());
            for g in guards.iter() {
                parts.push(match last_e {
                    None => g.window_all(),
                    Some(e) => g.window(e)?,
                });
            }
            let gens = guards.iter().map(|g| g.generation()).collect();
            (parts, gens)
        };
        Ok((SketchArtifact::merge_all(&parts)?, gens))
    }

    /// Exact cross-shard decayed snapshot: pools every shard's λ-weighted
    /// partials and scales once, so each epoch is weighted exactly as in a
    /// single pooled ring (shards rotate in lockstep). Degenerate λ are
    /// artifact-level merges of the per-shard degenerate snapshots.
    pub fn merged_decayed(&self, lambda: f64) -> Result<(SketchArtifact, Vec<u64>), ApiError> {
        if !(lambda.is_finite() && (0.0..=1.0).contains(&lambda)) {
            return Err(ApiError::InvalidConfig {
                field: "decay",
                reason: format!("lambda must be in [0, 1], got {lambda}"),
            });
        }
        if lambda == 1.0 {
            return self.merged_window(None);
        }
        if lambda == 0.0 {
            let (parts, gens) = {
                let guards = self.lock_all();
                let parts: Result<Vec<_>, _> =
                    guards.iter().map(|g| g.decayed(0.0)).collect();
                let gens = guards.iter().map(|g| g.generation()).collect::<Vec<_>>();
                (parts?, gens)
            };
            return Ok((SketchArtifact::merge_all(&parts)?, gens));
        }
        let guards = self.lock_all();
        let mut sum = CVec::zeros(self.spec.m);
        let mut weighted_count = 0.0f64;
        let mut count = 0usize;
        let mut bounds = Bounds::empty(self.spec.n_dims);
        for g in guards.iter() {
            let (s, wc, c, b) = g.decayed_parts(lambda);
            sum.axpy(1.0, &s);
            weighted_count += wc;
            count += c;
            bounds.merge(&b);
        }
        let gens = guards.iter().map(|g| g.generation()).collect();
        drop(guards);
        if count > 0 && weighted_count > 0.0 {
            sum.scale(count as f64 / weighted_count);
        }
        Ok((SketchArtifact { op: self.spec.clone(), sum, count, bounds, quant: None }, gens))
    }

    /// Per-shard counters (shard index order).
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let g = lock_recover(s);
                ShardStats {
                    shard: i,
                    rows_ingested: g.rows_ingested(),
                    surviving_rows: g.surviving_rows(),
                    epochs: g.epoch_count(),
                    generation: g.generation(),
                    current_epoch_id: g.current_epoch_id(),
                }
            })
            .collect()
    }

    /// One shard's epoch breakdown.
    pub fn epoch_stats(&self, shard: usize) -> Vec<EpochStats> {
        self.shard(shard).epoch_stats()
    }

    /// Run `f` against one locked shard (introspection escape hatch).
    pub fn with_shard<T>(&self, shard: usize, f: impl FnOnce(&SketchStore) -> T) -> T {
        f(&self.shard(shard))
    }

    // -- serialization ----------------------------------------------------

    /// Serialize the whole set: a `ckm-store-set` wrapper whose `shards`
    /// entries are ordinary `ckm-store` objects (shard `i` carrying salt
    /// `base_shard + i`).
    pub fn to_json(&self) -> Json {
        let guards = self.lock_all();
        Json::obj(vec![
            ("format", Json::Str("ckm-store-set".to_string())),
            ("version", Json::Num(STORE_SET_FORMAT_VERSION as f64)),
            ("base_shard", Json::Str(self.base_shard.to_string())),
            ("shards", Json::Arr(guards.iter().map(|g| g.to_json()).collect())),
        ])
    }

    /// Parse a serialized set, validating uniform provenance across
    /// shards and the `base_shard + i` salt layout.
    pub fn from_json(j: &Json) -> Result<ShardedStore, ApiError> {
        let bad = |msg: &str| ApiError::Format(format!("store-set: {msg}"));
        if j.get("format").as_str() != Some("ckm-store-set") {
            return Err(bad("not a ckm-store-set file (missing format tag)"));
        }
        let version = j.get("version").as_usize().ok_or_else(|| bad("version missing"))?;
        if !(1..=STORE_SET_FORMAT_VERSION as usize).contains(&version) {
            return Err(ApiError::UnsupportedVersion {
                found: version,
                supported: STORE_SET_FORMAT_VERSION,
            });
        }
        let base_shard = j
            .get("base_shard")
            .as_str()
            .and_then(|s| s.parse::<u64>().ok())
            .ok_or_else(|| bad("base_shard must be a decimal u64 string"))?;
        let shards_j = j.get("shards").as_arr().ok_or_else(|| bad("shards missing"))?;
        let stores =
            shards_j.iter().map(SketchStore::from_json).collect::<Result<Vec<_>, _>>()?;
        ShardedStore::from_stores(base_shard, stores)
    }

    /// Assemble a set from already-restored per-shard stores — the shared
    /// tail of the JSON and binary codecs. Validates uniform provenance
    /// across shards and the `base_shard + i` salt layout.
    pub(crate) fn from_stores(
        base_shard: u64,
        stores: Vec<SketchStore>,
    ) -> Result<ShardedStore, ApiError> {
        let bad = |msg: &str| ApiError::Format(format!("store-set: {msg}"));
        if stores.is_empty() {
            return Err(bad("a store set holds at least one shard"));
        }
        let mut shards = Vec::with_capacity(stores.len());
        let mut spec: Option<OpSpec> = None;
        let mut quantization = None;
        for (i, store) in stores.into_iter().enumerate() {
            if store.shard() != base_shard + i as u64 {
                return Err(bad(&format!(
                    "shard {i} carries salt {} (expected base {base_shard} + {i})",
                    store.shard()
                )));
            }
            match spec.as_ref() {
                None => {
                    spec = Some(store.spec().clone());
                    quantization = store.quantization();
                }
                Some(s) if *s == *store.spec() && quantization == store.quantization() => {}
                Some(s) => {
                    return Err(ApiError::OperatorMismatch {
                        left: s.describe(),
                        right: store.spec().describe(),
                    })
                }
            }
            shards.push(Mutex::new(store));
        }
        Ok(ShardedStore {
            shards,
            spec: spec.expect("at least one shard parsed"),
            quantization,
            base_shard,
        })
    }

    /// A consistent point-in-time copy of every shard, taken under all
    /// shard locks in index order and released immediately — the cheap
    /// first half of a checkpoint. Serialization (the expensive half)
    /// runs on the clones with **no** store lock held, so producers keep
    /// ingesting while a checkpoint encodes and streams.
    pub fn snapshot(&self) -> Vec<SketchStore> {
        self.lock_all().iter().map(|g| (**g).clone()).collect()
    }

    /// Checkpoint as pretty-printed JSON (atomic write — a crash never
    /// tears the previous checkpoint).
    pub fn to_file<P: AsRef<Path>>(&self, path: P) -> Result<(), ApiError> {
        crate::util::fs::atomic_write(path, self.to_json().to_pretty().as_bytes())?;
        Ok(())
    }

    /// Checkpoint as a binary CKMC container (the compact codec).
    pub fn to_binary_file<P: AsRef<Path>>(&self, path: P) -> Result<(), ApiError> {
        let image = crate::store::checkpoint::store_set_image(self.base_shard, &self.snapshot());
        crate::util::fs::atomic_write(path, &image.to_bytes())?;
        Ok(())
    }

    /// Load a checkpoint from either codec, sniffed by magic (`CKMC` =
    /// binary container, else JSON).
    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<ShardedStore, ApiError> {
        let bytes = std::fs::read(path)?;
        if crate::util::container::is_container(&bytes) {
            return crate::store::checkpoint::store_set_from_container(&bytes);
        }
        let text = std::str::from_utf8(&bytes)
            .map_err(|_| ApiError::Format("store file is neither CKMC nor UTF-8 JSON".into()))?;
        ShardedStore::from_json(&Json::parse(text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::RadiusKind;
    use crate::testing::gen;
    use crate::util::rng::Rng;

    fn spec(seed: u64, m: usize, n: usize) -> OpSpec {
        OpSpec::derive(seed, RadiusKind::AdaptedRadius, 1.0, m, n).0
    }

    #[test]
    fn producer_sharding_is_deterministic_and_total() {
        let set = ShardedStore::create(spec(1, 8, 2), None, 0, 4, None, CompactionPolicy::None)
            .unwrap();
        for p in ["alpha", "bravo", "charlie", "delta", ""] {
            let s = set.shard_for_producer(p);
            assert!(s < 4);
            assert_eq!(s, set.shard_for_producer(p));
        }
    }

    #[test]
    fn merged_window_is_exact_across_shards() {
        // Quantized: the merged artifact must equal the facade sketch of
        // the concatenated rows per shard, merged — bit for bit.
        let mode = Some(QuantizationMode::OneBit);
        let set = ShardedStore::create(spec(2, 16, 3), mode, 10, 2, None, CompactionPolicy::None)
            .unwrap();
        let mut rng = Rng::new(3);
        let rows0 = gen::mat_normal(&mut rng, 21, 3);
        let rows1 = gen::mat_normal(&mut rng, 13, 3);
        set.ingest(0, &rows0);
        set.ingest(1, &rows1);
        let (merged, gens) = set.merged_window(None).unwrap();
        assert_eq!(gens, vec![1, 1]);
        assert_eq!(merged.count, 34);

        let single = |shard: u64, rows: &[f64]| {
            let store = SketchStore::create(spec(2, 16, 3), mode, shard, None).unwrap();
            let mut store = store;
            store.ingest(rows);
            store.window_all()
        };
        let expected = single(10, &rows0).merge(&single(11, &rows1)).unwrap();
        assert_eq!(merged, expected);
    }

    #[test]
    fn try_absorb_rejects_foreign_chunks_without_panicking() {
        let mode = Some(QuantizationMode::OneBit);
        let set = ShardedStore::create(spec(4, 8, 2), mode, 0, 2, None, CompactionPolicy::None)
            .unwrap();
        let mut rng = Rng::new(5);
        let rows = gen::mat_normal(&mut rng, 4, 2);
        // a chunk sketched under shard 1's dither stream, shipped to shard 0
        let ctx1 = set.context(1);
        let off = set.reserve(1, 4);
        let chunk = ctx1.sketch_chunk(&rows, off);
        assert!(matches!(
            set.try_absorb(0, chunk.clone()),
            Err(ApiError::ServiceProtocol(_))
        ));
        // untouched: nothing was merged
        assert_eq!(set.shard_stats()[0].rows_ingested, 0);
        // the right shard takes it
        assert_eq!(set.try_absorb(1, chunk).unwrap(), 4);
        // a dense chunk against a quantized store
        let dense_set =
            ShardedStore::create(spec(4, 8, 2), None, 0, 1, None, CompactionPolicy::None)
                .unwrap();
        let dense_chunk = dense_set.context(0).sketch_chunk(&rows, 0);
        assert!(matches!(
            set.try_absorb(0, dense_chunk),
            Err(ApiError::ServiceProtocol(_))
        ));
    }

    #[test]
    fn merged_decayed_matches_single_pooled_ring() {
        // Two dense shards rotating in lockstep vs one pooled store fed
        // the same rows per epoch: pooled λ-weighting must agree.
        let set = ShardedStore::create(spec(6, 8, 2), None, 0, 2, None, CompactionPolicy::None)
            .unwrap();
        let mut pooled = SketchStore::create(spec(6, 8, 2), None, 0, None).unwrap();
        let mut rng = Rng::new(7);
        for _ in 0..3 {
            let a = gen::mat_normal(&mut rng, 5, 2);
            let b = gen::mat_normal(&mut rng, 9, 2);
            set.ingest(0, &a);
            set.ingest(1, &b);
            pooled.ingest(&a);
            pooled.ingest(&b);
            set.rotate_all();
            pooled.rotate();
        }
        let (merged, _) = set.merged_decayed(0.5).unwrap();
        let expected = pooled.decayed(0.5).unwrap();
        assert_eq!(merged.count, expected.count);
        assert!(merged.sum.max_abs_diff(&expected.sum) <= 1e-12 * (1.0 + expected.count as f64));
        // λ = 1 short-circuits to the exact window merge
        let (w1, _) = set.merged_decayed(1.0).unwrap();
        assert_eq!(w1.count, pooled.window_all().count);
    }

    #[test]
    fn set_serialization_roundtrips_and_validates_layout() {
        let mode = Some(QuantizationMode::Bits(2));
        let set =
            ShardedStore::create(spec(8, 8, 2), mode, 3, 2, Some(4), CompactionPolicy::Exponential)
                .unwrap();
        let mut rng = Rng::new(9);
        for _ in 0..3 {
            set.ingest(0, &gen::mat_normal(&mut rng, 4, 2));
            set.ingest(1, &gen::mat_normal(&mut rng, 2, 2));
            set.rotate_all();
        }
        let j = set.to_json();
        let back = ShardedStore::from_json(&Json::parse(&j.to_pretty()).unwrap()).unwrap();
        assert_eq!(back.n_shards(), 2);
        assert_eq!(back.base_shard(), 3);
        assert_eq!(back.quantization(), set.quantization());
        let (a, _) = set.merged_window(None).unwrap();
        let (b, _) = back.merged_window(None).unwrap();
        assert_eq!(a, b);
        // a shard whose salt breaks the base + i layout is rejected
        let mut j2 = set.to_json();
        if let Json::Obj(o) = &mut j2 {
            o.insert("base_shard".to_string(), Json::Str("7".to_string()));
        }
        assert!(ShardedStore::from_json(&j2).is_err());
    }
}
