//! The epoch ring: per-epoch sketch buckets with exact window merges and
//! exponentially-decayed snapshots.
//!
//! Every epoch holds its own accumulator (dense [`SketchAccumulator`] or
//! integer [`QuantizedAccumulator`]); rows always land in the *newest*
//! epoch, [`SketchStore::rotate`] seals it, and retention is pure bucket
//! drop — the merge algebra is associative, so nothing is ever subtracted
//! and a window over surviving epochs is exactly the sketch of their rows.
//!
//! Quantized stores key the dither stream by the store-lifetime row index
//! (reserved at ingest), so an epoch replay of a stream produces the same
//! integer state as a single uninterrupted pass — bit for bit — and a
//! checkpointed store resumes dither-compatibly after
//! [`SketchStore::from_file`].
//!
//! Ingest comes in two shapes: the synchronous [`SketchStore::ingest`]
//! (sketch math under the caller's exclusivity — the single-producer
//! path), and **two-phase ingest** for concurrent producers:
//! [`SketchStore::reserve_rows`] hands out the global row-index range
//! under a short lock, [`SketchContext::sketch_chunk`] runs the full
//! `X·Wᵀ` + trig sweep with *no* lock held, and [`SketchStore::absorb`]
//! merges the finished chunk under a second short lock. Because the
//! dither keys come from the reservation, a single producer's two-phase
//! sequence is bit-identical to the synchronous path, and reserved-but-
//! never-absorbed ranges (a dead producer) merely skip dither keys.

use crate::api::{ApiError, OpSpec, SketchArtifact};
use crate::data::dataset::Bounds;
use crate::linalg::CVec;
use crate::sketch::quantize::{self, QuantizationMode, QuantizedAccumulator};
use crate::sketch::{SketchAccumulator, SketchOp};
use crate::util::json::Json;
use std::collections::VecDeque;
use std::path::Path;

/// Version of the store JSON schema this build can read and (when the
/// ring uses features version 1 lacks — compaction spans) write. Plain
/// uncompacted rings still serialize as version 1, byte-identical to
/// earlier builds. Epoch entries are ordinary artifact-v2 objects (see
/// [`crate::api::SKETCH_FORMAT_VERSION`]).
pub const STORE_FORMAT_VERSION: u32 = 2;

/// Retention shape for sealed epochs (see [`SketchStore::with_compaction`]).
///
/// `None` keeps every sealed epoch as its own bucket (bounded only by the
/// ring capacity). `Exponential` maintains an exponential histogram over
/// sealed epochs: at most two buckets per power-of-two span, merging the
/// two oldest equal-span buckets whenever a third appears, so `E` original
/// epochs survive in `O(log E)` buckets. Merges reuse the exact epoch
/// merge algebra (integer adds for quantized rings, fixed-order dense
/// sums), so `window_all()` over a compacted ring covers exactly the same
/// rows — compaction only coarsens which *boundaries* a window can cut at.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CompactionPolicy {
    #[default]
    None,
    Exponential,
}

impl CompactionPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            CompactionPolicy::None => "none",
            CompactionPolicy::Exponential => "exponential",
        }
    }

    pub fn parse(s: &str) -> Option<CompactionPolicy> {
        match s {
            "none" => Some(CompactionPolicy::None),
            "exponential" | "exp" => Some(CompactionPolicy::Exponential),
            _ => None,
        }
    }
}

/// One epoch bucket: dense or integer accumulator state.
#[derive(Clone, Debug, PartialEq)]
enum EpochAcc {
    Dense(SketchAccumulator),
    Quantized(QuantizedAccumulator),
}

/// A sealed-or-current epoch of the ring.
#[derive(Clone, Debug, PartialEq)]
struct EpochSketch {
    /// Monotonic epoch id (survives eviction: ids never reset). A
    /// compacted bucket keeps the *newest* id it absorbed, so ids stay
    /// strictly increasing along the ring.
    id: u64,
    /// Store-lifetime index of the first row this epoch absorbed (the
    /// quantized dither key; informational for dense stores).
    start_row: usize,
    /// How many original (rotation-granularity) epochs this bucket covers.
    /// 1 until compaction merges buckets.
    span: u64,
    acc: EpochAcc,
}

impl EpochSketch {
    fn count(&self) -> usize {
        match &self.acc {
            EpochAcc::Dense(a) => a.count,
            EpochAcc::Quantized(a) => a.count,
        }
    }

    fn bounds(&self) -> &Bounds {
        match &self.acc {
            EpochAcc::Dense(a) => &a.bounds,
            EpochAcc::Quantized(a) => &a.bounds,
        }
    }

    /// `into += w · (this epoch's unnormalized sum)` — the decayed-snapshot
    /// accumulation step (quantized epochs contribute their debiased sums).
    fn add_scaled_sum(&self, w: f64, into: &mut CVec) {
        match &self.acc {
            EpochAcc::Dense(a) => into.axpy(w, &a.sum),
            EpochAcc::Quantized(a) => into.axpy(w, &a.dequantized_sum()),
        }
    }

    /// This epoch alone, as a durable artifact.
    fn artifact(&self, spec: &OpSpec) -> SketchArtifact {
        match &self.acc {
            EpochAcc::Dense(a) => SketchArtifact {
                op: spec.clone(),
                sum: a.sum.clone(),
                count: a.count,
                bounds: a.bounds.clone(),
                quant: None,
            },
            EpochAcc::Quantized(a) => SketchArtifact::from_quantized(spec.clone(), a),
        }
    }
}

/// Checkpoint-header parts shared by the JSON and binary store codecs
/// (everything [`SketchStore::restore`] needs besides the epochs).
#[derive(Clone, Debug)]
pub(crate) struct RestoredHeader {
    pub shard: u64,
    pub quantization: Option<QuantizationMode>,
    pub capacity: Option<usize>,
    pub compaction: CompactionPolicy,
}

/// One decoded epoch headed into [`SketchStore::restore`].
#[derive(Clone, Debug)]
pub(crate) struct RestoredEpoch {
    pub id: u64,
    pub start_row: usize,
    pub span: u64,
    pub artifact: SketchArtifact,
}

/// Introspection record for one epoch of the ring.
#[derive(Clone, Debug, PartialEq)]
pub struct EpochStats {
    pub id: u64,
    pub start_row: usize,
    pub rows: usize,
    /// Original epochs this bucket covers (1 unless compacted).
    pub span: u64,
}

/// Everything a producer needs to sketch a chunk *outside* the store lock
/// (phase 2 of two-phase ingest): the operator (with its trig backend),
/// the quantization mode and the dither-stream seed. Obtained once per
/// producer from [`SketchStore::sketch_context`]; immutable for the life
/// of the store, so a clone never goes stale.
#[derive(Clone, Debug)]
pub struct SketchContext {
    op: SketchOp,
    quantization: Option<QuantizationMode>,
    dither_seed: u64,
}

impl SketchContext {
    /// Rebuild a context from operator provenance — the service client's
    /// entry point: the daemon's `HelloAck` carries (spec, quantization,
    /// dither seed), and materializing the spec re-derives the frequency
    /// matrix and verifies its checksum, so a client never sketches under
    /// an operator the daemon didn't prove.
    pub fn from_parts(
        spec: &OpSpec,
        quantization: Option<QuantizationMode>,
        dither_seed: u64,
    ) -> Result<SketchContext, ApiError> {
        if let Some(mode) = quantization {
            mode.validate()
                .map_err(|reason| ApiError::InvalidConfig { field: "quantization", reason })?;
        }
        let op = spec.materialize()?;
        Ok(SketchContext {
            op,
            quantization: quantization.map(QuantizationMode::normalized),
            dither_seed,
        })
    }

    pub fn n_dims(&self) -> usize {
        self.op.n_dims()
    }

    pub fn m(&self) -> usize {
        self.op.m()
    }

    pub fn quantization(&self) -> Option<QuantizationMode> {
        self.quantization
    }

    pub fn dither_seed(&self) -> u64 {
        self.dither_seed
    }

    /// Run the full sketch math for one chunk whose first row holds the
    /// reserved global index `row_offset` (see
    /// [`SketchStore::reserve_rows`]). No locks touched: this is the
    /// expensive part of ingest, and any number of producers run it
    /// concurrently. Quantized chunks key their dithers off the reserved
    /// range, so a single producer's reserve→sketch→absorb sequence is
    /// bit-identical to the synchronous [`SketchStore::ingest`] path.
    pub fn sketch_chunk(&self, rows: &[f64], row_offset: usize) -> ChunkSketch {
        let n = self.op.n_dims();
        assert_eq!(rows.len() % n, 0, "non-integral row chunk");
        match self.quantization {
            None => {
                let mut acc = SketchAccumulator::new(self.op.m(), n);
                acc.update(&self.op, rows);
                ChunkSketch::Dense(acc)
            }
            Some(mode) => {
                let mut acc =
                    QuantizedAccumulator::new(self.op.m(), n, mode, self.dither_seed);
                acc.update(&self.op, rows, row_offset);
                ChunkSketch::Quantized(acc)
            }
        }
    }
}

/// An outside-sketched ingest quantum, ready to be merged under a short
/// lock by [`SketchStore::absorb`].
#[derive(Clone, Debug, PartialEq)]
pub enum ChunkSketch {
    Dense(SketchAccumulator),
    Quantized(QuantizedAccumulator),
}

impl ChunkSketch {
    pub fn count(&self) -> usize {
        match self {
            ChunkSketch::Dense(a) => a.count,
            ChunkSketch::Quantized(a) => a.count,
        }
    }
}

/// An epoch-bucketed sketch store: the state object of a long-running
/// clustering service.
///
/// Rows stream in through [`SketchStore::ingest`]; [`SketchStore::rotate`]
/// advances time (one bucket per hour, day, … — the caller's clock);
/// [`SketchStore::window`] answers "clusters over the last `e` epochs" and
/// [`SketchStore::decayed`] "clusters with exponentially faded history",
/// both as ordinary [`SketchArtifact`]s the unchanged CLOMPR decoder
/// consumes. Construct via [`crate::api::Ckm::store`] (facade, validated
/// config) or [`SketchStore::create`] (explicit provenance).
#[derive(Clone, Debug)]
pub struct SketchStore {
    spec: OpSpec,
    op: SketchOp,
    quantization: Option<QuantizationMode>,
    shard: u64,
    dither_seed: u64,
    /// Max epoch *buckets* retained (`None` = unbounded ring).
    capacity: Option<usize>,
    /// Sealed-epoch retention shape (see [`CompactionPolicy`]).
    compaction: CompactionPolicy,
    /// Oldest at the front, current (newest) at the back; never empty.
    epochs: VecDeque<EpochSketch>,
    next_epoch_id: u64,
    /// Store-lifetime rows (keeps counting across eviction — the quantized
    /// dither key must never be reused).
    rows_ingested: usize,
    /// Global row indices handed out by [`SketchStore::reserve_rows`]
    /// (two-phase ingest). Runs ahead of `rows_ingested` only while a
    /// reserved chunk is being sketched outside the lock; equal at rest.
    /// Not serialized: a loaded store resumes both counters from
    /// `rows_ingested`.
    rows_reserved: usize,
    /// Bumped on every mutation; snapshot caches key off it.
    generation: u64,
}

impl SketchStore {
    /// Build a store from operator provenance (the checksum is verified by
    /// re-deriving the frequency matrix). `capacity` is the ring size in
    /// epochs (`None` = retain everything); `shard` salts the quantized
    /// dither stream exactly as in [`crate::api::CkmBuilder::shard`].
    pub fn create(
        spec: OpSpec,
        quantization: Option<QuantizationMode>,
        shard: u64,
        capacity: Option<usize>,
    ) -> Result<SketchStore, ApiError> {
        if capacity == Some(0) {
            return Err(ApiError::InvalidConfig {
                field: "window",
                reason: "need a window of at least one epoch".into(),
            });
        }
        if let Some(mode) = quantization {
            mode.validate()
                .map_err(|reason| ApiError::InvalidConfig { field: "quantization", reason })?;
        }
        let op = spec.materialize()?;
        let dither_seed = quantize::dither_seed_for_shard(spec.seed, shard);
        let mut store = SketchStore {
            spec,
            op,
            quantization: quantization.map(QuantizationMode::normalized),
            shard,
            dither_seed,
            capacity,
            compaction: CompactionPolicy::None,
            epochs: VecDeque::new(),
            next_epoch_id: 0,
            rows_ingested: 0,
            rows_reserved: 0,
            generation: 0,
        };
        store.push_epoch();
        Ok(store)
    }

    /// Choose the sealed-epoch retention shape (builder-style). Safe to
    /// call on a live store: the policy only takes effect at the next
    /// [`SketchStore::rotate`].
    pub fn with_compaction(mut self, policy: CompactionPolicy) -> SketchStore {
        self.compaction = policy;
        self
    }

    fn push_epoch(&mut self) {
        let acc = match self.quantization {
            None => EpochAcc::Dense(SketchAccumulator::new(self.spec.m, self.spec.n_dims)),
            Some(mode) => EpochAcc::Quantized(QuantizedAccumulator::new(
                self.spec.m,
                self.spec.n_dims,
                mode,
                self.dither_seed,
            )),
        };
        self.epochs.push_back(EpochSketch {
            id: self.next_epoch_id,
            start_row: self.rows_ingested,
            span: 1,
            acc,
        });
        self.next_epoch_id += 1;
    }

    // -- ingest / rotate --------------------------------------------------

    /// Absorb row-major rows into the current (newest) epoch, synchronously
    /// (sketch math under the caller's exclusivity). Returns the number of
    /// rows absorbed. Concurrent producers should prefer the two-phase
    /// [`SketchStore::reserve_rows`] → [`SketchContext::sketch_chunk`] →
    /// [`SketchStore::absorb`] flow, which keeps the sketch math outside
    /// any store lock.
    pub fn ingest(&mut self, rows: &[f64]) -> usize {
        let n = self.spec.n_dims;
        assert_eq!(rows.len() % n, 0, "non-integral row ingest");
        let n_rows = rows.len() / n;
        if n_rows == 0 {
            return 0;
        }
        let offset = self.reserve_rows(n_rows);
        let ep = self.epochs.back_mut().expect("store holds at least one epoch");
        match &mut ep.acc {
            EpochAcc::Dense(a) => a.update(&self.op, rows),
            EpochAcc::Quantized(a) => a.update(&self.op, rows, offset),
        }
        self.rows_ingested += n_rows;
        self.generation += 1;
        n_rows
    }

    /// Phase 1 of two-phase ingest: reserve the next `n_rows` global row
    /// indices (the quantized dither keys) and return the first. A cheap
    /// counter bump — this is the only part of the sketch that *must*
    /// happen under the store lock, so a server holds the lock for two
    /// counter updates per chunk instead of the full `X·Wᵀ` + trig sweep.
    /// Reserved ranges are never reused, even if the producer dies before
    /// [`SketchStore::absorb`] (an abandoned reservation just skips keys,
    /// which the dither algebra is indifferent to).
    pub fn reserve_rows(&mut self, n_rows: usize) -> usize {
        let offset = self.rows_reserved;
        self.rows_reserved += n_rows;
        offset
    }

    /// The immutable context a producer needs to run phase 2 (the sketch
    /// math) outside the store lock: operator, quantization mode, dither
    /// seed. Cheap to clone once per producer/session.
    pub fn sketch_context(&self) -> SketchContext {
        SketchContext {
            op: self.op.clone(),
            quantization: self.quantization,
            dither_seed: self.dither_seed,
        }
    }

    /// Phase 3 of two-phase ingest: exactly merge an outside-sketched
    /// chunk into the *current* epoch (rows belong to whichever epoch is
    /// current when their merge lands — the documented concurrency
    /// semantics). Integer merge for quantized chunks, one `axpy` per
    /// component for dense ones; both far cheaper than the sketch itself.
    /// Returns the rows absorbed.
    ///
    /// Panics if the chunk kind disagrees with the store's quantization or
    /// was sketched under a different dither stream — producers must build
    /// chunks through this store's [`SketchStore::sketch_context`].
    pub fn absorb(&mut self, chunk: ChunkSketch) -> usize {
        let count = chunk.count();
        if count == 0 {
            return 0;
        }
        let ep = self.epochs.back_mut().expect("store holds at least one epoch");
        match (&mut ep.acc, &chunk) {
            (EpochAcc::Dense(a), ChunkSketch::Dense(c)) => a.merge(c),
            (EpochAcc::Quantized(a), ChunkSketch::Quantized(c)) => a.merge(c),
            _ => panic!("chunk sketch kind does not match the store's quantization"),
        }
        self.rows_ingested += count;
        self.generation += 1;
        count
    }

    /// Seal the current epoch and open a fresh one. Under
    /// [`CompactionPolicy::Exponential`] the sealed buckets are then
    /// re-compacted (exact merges), and if the ring still exceeds its
    /// capacity the oldest bucket(s) are dropped — eviction is bucket drop,
    /// never subtraction, so surviving windows stay exact. Returns the
    /// evicted epoch ids (empty when nothing aged out; a compacted bucket
    /// reports the newest id it absorbed).
    pub fn rotate(&mut self) -> Vec<u64> {
        self.push_epoch();
        self.generation += 1;
        self.compact();
        let mut evicted = Vec::new();
        if let Some(cap) = self.capacity {
            while self.epochs.len() > cap {
                let old = self.epochs.pop_front().expect("len > cap >= 1");
                evicted.push(old.id);
            }
        }
        evicted
    }

    /// Exponential-histogram maintenance over the *sealed* buckets (the
    /// current epoch is never compacted): whenever three buckets share a
    /// span, the two oldest — always adjacent, since spans are
    /// non-increasing toward the newest end — merge into one double-span
    /// bucket, cascading until every span class holds at most two.
    fn compact(&mut self) {
        if self.compaction != CompactionPolicy::Exponential {
            return;
        }
        loop {
            let sealed = self.epochs.len() - 1; // current epoch excluded
            let mut merged_at: Option<usize> = None;
            let mut span = 1u64;
            loop {
                let idxs: Vec<usize> =
                    (0..sealed).filter(|&i| self.epochs[i].span == span).collect();
                if idxs.len() >= 3 {
                    debug_assert_eq!(idxs[1], idxs[0] + 1, "equal-span buckets are adjacent");
                    merged_at = Some(idxs[0]);
                    break;
                }
                match (0..sealed).map(|i| self.epochs[i].span).filter(|&s| s > span).min() {
                    Some(next) => span = next,
                    None => break,
                }
            }
            match merged_at {
                Some(i) => self.merge_adjacent_epochs(i),
                None => break,
            }
        }
    }

    /// Merge bucket `i` (older) with bucket `i + 1` (newer) in place.
    fn merge_adjacent_epochs(&mut self, i: usize) {
        let newer = self.epochs.remove(i + 1).expect("bucket index in range");
        let older = &mut self.epochs[i];
        older.id = newer.id; // newest id absorbed: ids stay strictly increasing
        older.span += newer.span;
        match (&mut older.acc, newer.acc) {
            (EpochAcc::Dense(a), EpochAcc::Dense(b)) => a.merge(&b),
            (EpochAcc::Quantized(a), EpochAcc::Quantized(b)) => a.merge(&b),
            _ => unreachable!("ring holds a uniform accumulator kind"),
        }
    }

    // -- snapshots --------------------------------------------------------

    /// Merge the newest `last_e` *original* epochs into one artifact
    /// (clamped to the surviving span total). Exact: dense sums add
    /// associatively (merge order is fixed oldest→newest), integer level
    /// sums add exactly. On a compacted ring the window widens to the
    /// nearest bucket boundary at the old end — a bucket is indivisible,
    /// so the answer covers *at least* the requested epochs.
    pub fn window(&self, last_e: usize) -> Result<SketchArtifact, ApiError> {
        if last_e == 0 {
            return Err(ApiError::InvalidConfig {
                field: "window",
                reason: "need a window of at least one epoch".into(),
            });
        }
        let mut start = self.epochs.len();
        let mut covered = 0u64;
        while start > 0 && covered < last_e as u64 {
            start -= 1;
            covered += self.epochs[start].span;
        }
        Ok(self.merge_from(start))
    }

    /// Merge every surviving epoch ("all time", within retention).
    pub fn window_all(&self) -> SketchArtifact {
        self.merge_from(0)
    }

    fn merge_from(&self, start: usize) -> SketchArtifact {
        match self.quantization {
            None => {
                let mut acc: Option<SketchAccumulator> = None;
                for ep in self.epochs.iter().skip(start) {
                    let EpochAcc::Dense(a) = &ep.acc else {
                        unreachable!("dense store holds a quantized epoch")
                    };
                    match acc.as_mut() {
                        None => acc = Some(a.clone()),
                        Some(m) => m.merge(a),
                    }
                }
                let acc = acc.expect("store holds at least one epoch");
                SketchArtifact {
                    op: self.spec.clone(),
                    sum: acc.sum,
                    count: acc.count,
                    bounds: acc.bounds,
                    quant: None,
                }
            }
            Some(_) => {
                let mut acc: Option<QuantizedAccumulator> = None;
                for ep in self.epochs.iter().skip(start) {
                    let EpochAcc::Quantized(a) = &ep.acc else {
                        unreachable!("quantized store holds a dense epoch")
                    };
                    match acc.as_mut() {
                        None => acc = Some(a.clone()),
                        Some(m) => m.merge(a),
                    }
                }
                let acc = acc.expect("store holds at least one epoch");
                SketchArtifact::from_quantized(self.spec.clone(), &acc)
            }
        }
    }

    /// Exponentially-decayed snapshot: epoch at age `a` (0 = newest) is
    /// weighted `λ^a` on both its sum and its count, so the artifact's
    /// normalized sketch `z()` is the λ-weighted empirical characteristic
    /// function `Σ λ^a·sum_a / Σ λ^a·count_a` — a reweighted empirical
    /// measure, which CLOMPR decodes unchanged.
    ///
    /// Degenerate ends are served exactly: `decayed(0.0)` is the newest
    /// epoch alone (`0^0 = 1`) and `decayed(1.0)` is the plain
    /// [`SketchStore::window_all`] merge. Interior λ returns a *dense*
    /// artifact whose `count` is the raw surviving-row total and whose
    /// `sum` is rescaled so `z()` equals the weighted sketch (fractional
    /// weights leave the integer payload representation, so a quantized
    /// store's decayed snapshot is dense by construction).
    pub fn decayed(&self, lambda: f64) -> Result<SketchArtifact, ApiError> {
        if !(lambda.is_finite() && (0.0..=1.0).contains(&lambda)) {
            return Err(ApiError::InvalidConfig {
                field: "decay",
                reason: format!("lambda must be in [0, 1], got {lambda}"),
            });
        }
        if lambda == 1.0 {
            return Ok(self.window_all());
        }
        if lambda == 0.0 {
            return Ok(self.merge_from(self.epochs.len() - 1));
        }
        let (mut sum, weighted_count, count, bounds) = self.decayed_parts(lambda);
        if count > 0 && weighted_count > 0.0 {
            sum.scale(count as f64 / weighted_count);
        }
        Ok(SketchArtifact { op: self.spec.clone(), sum, count, bounds, quant: None })
    }

    /// Unscaled λ-weighted partials: `(Σ λ^a·sum_a, Σ λ^a·count_a,
    /// Σ count_a, merged bounds)`. Ages count *original* epochs (a
    /// compacted bucket is weighted by the age of its newest edge), so
    /// shard rings that rotate in lockstep can pool their partials and
    /// scale once — the cross-shard decayed snapshot then weights every
    /// epoch exactly as a single pooled ring would.
    pub(crate) fn decayed_parts(&self, lambda: f64) -> (CVec, f64, usize, Bounds) {
        let mut sum = CVec::zeros(self.spec.m);
        let mut weighted_count = 0.0f64;
        let mut count = 0usize;
        let mut bounds = Bounds::empty(self.spec.n_dims);
        // Accumulate oldest→newest (the historical order — keeps dense
        // decayed snapshots bit-identical on uncompacted rings); the age
        // of a bucket is the span total of everything newer than it.
        let mut newer_span: u64 = self.epochs.iter().map(|ep| ep.span).sum();
        for ep in self.epochs.iter() {
            newer_span -= ep.span;
            let w = lambda.powi(newer_span as i32);
            ep.add_scaled_sum(w, &mut sum);
            weighted_count += w * ep.count() as f64;
            count += ep.count();
            bounds.merge(ep.bounds());
        }
        (sum, weighted_count, count, bounds)
    }

    // -- introspection ----------------------------------------------------

    pub fn spec(&self) -> &OpSpec {
        &self.spec
    }

    pub fn n_dims(&self) -> usize {
        self.spec.n_dims
    }

    pub fn m(&self) -> usize {
        self.spec.m
    }

    pub fn quantization(&self) -> Option<QuantizationMode> {
        self.quantization
    }

    pub fn shard(&self) -> u64 {
        self.shard
    }

    /// The dither-stream seed quantized epochs are keyed with.
    pub fn dither_seed(&self) -> u64 {
        self.dither_seed
    }

    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    pub fn compaction(&self) -> CompactionPolicy {
        self.compaction
    }

    /// Surviving epochs in the ring (≥ 1).
    pub fn epoch_count(&self) -> usize {
        self.epochs.len()
    }

    /// Rows across surviving epochs.
    pub fn surviving_rows(&self) -> usize {
        self.epochs.iter().map(EpochSketch::count).sum()
    }

    /// Store-lifetime rows (monotonic; includes evicted epochs).
    pub fn rows_ingested(&self) -> usize {
        self.rows_ingested
    }

    /// Mutation counter (snapshot caches key off it). Every `ingest`,
    /// `absorb` and `rotate` bumps it, and a store restored from a file
    /// derives a non-zero generation from its persisted progress, so a
    /// cache keyed on generation can never confuse a freshly-restored
    /// store with its pre-restore state at generation 0.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Force the generation strictly past `floor`. Used when a restored
    /// store replaces a live one (see `SketchServer::restore`): whatever
    /// generation the old store had reached, the replacement must not
    /// collide with it, or a generation-keyed cache could serve a solve
    /// computed against pre-checkpoint state.
    pub fn bump_generation_past(&mut self, floor: u64) {
        if self.generation <= floor {
            self.generation = floor + 1;
        }
    }

    pub fn current_epoch_id(&self) -> u64 {
        self.epochs.back().expect("store holds at least one epoch").id
    }

    /// The id the next rotation will open (strictly above every live id).
    pub fn next_epoch_id(&self) -> u64 {
        self.next_epoch_id
    }

    pub fn oldest_epoch_id(&self) -> u64 {
        self.epochs.front().expect("store holds at least one epoch").id
    }

    /// Per-epoch introspection, oldest first.
    pub fn epoch_stats(&self) -> Vec<EpochStats> {
        self.epochs
            .iter()
            .map(|ep| EpochStats {
                id: ep.id,
                start_row: ep.start_row,
                rows: ep.count(),
                span: ep.span,
            })
            .collect()
    }

    /// Every surviving epoch as its own artifact, oldest first.
    pub fn epoch_artifacts(&self) -> Vec<SketchArtifact> {
        self.epochs.iter().map(|ep| ep.artifact(&self.spec)).collect()
    }

    // -- serialization ----------------------------------------------------

    /// Serialize the whole ring: one versioned JSON object whose `epochs`
    /// entries are ordinary artifact-v2 objects plus their epoch id and
    /// start row. Uncompacted rings write version 1 (byte-identical to
    /// earlier builds); a compaction policy or a multi-span bucket
    /// promotes the file to version 2.
    pub fn to_json(&self) -> Json {
        let epochs = self
            .epochs
            .iter()
            .map(|ep| {
                let mut fields = vec![
                    ("id", Json::Num(ep.id as f64)),
                    ("start_row", Json::Num(ep.start_row as f64)),
                ];
                if ep.span > 1 {
                    fields.push(("span", Json::Num(ep.span as f64)));
                }
                fields.push(("artifact", ep.artifact(&self.spec).to_json()));
                Json::obj(fields)
            })
            .collect();
        let v2 = self.compaction != CompactionPolicy::None
            || self.epochs.iter().any(|ep| ep.span > 1);
        let mut fields = vec![
            ("format", Json::Str("ckm-store".to_string())),
            ("version", Json::Num(if v2 { 2.0 } else { 1.0 })),
        ];
        if self.compaction != CompactionPolicy::None {
            fields.push(("compaction", Json::Str(self.compaction.name().to_string())));
        }
        fields.extend(vec![
            ("shard", Json::Str(self.shard.to_string())),
            (
                "quant_bits",
                match self.quantization {
                    None => Json::Null,
                    Some(mode) => Json::Num(mode.bits() as f64),
                },
            ),
            (
                "capacity",
                match self.capacity {
                    None => Json::Null,
                    Some(c) => Json::Num(c as f64),
                },
            ),
            ("next_epoch_id", Json::Num(self.next_epoch_id as f64)),
            ("rows_ingested", Json::Num(self.rows_ingested as f64)),
            ("epochs", Json::Arr(epochs)),
        ]);
        Json::obj(fields)
    }

    /// Parse a serialized store, re-deriving and checksum-verifying the
    /// operator once and validating the ring invariants (uniform operator
    /// and quantization across epochs, strictly increasing ids, the
    /// newest epoch accounting for `rows_ingested`).
    pub fn from_json(j: &Json) -> Result<SketchStore, ApiError> {
        let bad = |msg: &str| ApiError::Format(format!("store: {msg}"));
        if j.get("format").as_str() != Some("ckm-store") {
            return Err(bad("not a ckm-store file (missing format tag)"));
        }
        let version = j.get("version").as_usize().ok_or_else(|| bad("version missing"))?;
        if !(1..=STORE_FORMAT_VERSION as usize).contains(&version) {
            return Err(ApiError::UnsupportedVersion {
                found: version,
                supported: STORE_FORMAT_VERSION,
            });
        }
        let compaction = match j.get("compaction") {
            Json::Null => CompactionPolicy::None,
            c => c
                .as_str()
                .and_then(CompactionPolicy::parse)
                .ok_or_else(|| bad("compaction must be \"none\" or \"exponential\""))?,
        };
        if compaction != CompactionPolicy::None && version < 2 {
            return Err(bad("compaction policy requires store format version >= 2"));
        }
        let shard = j
            .get("shard")
            .as_str()
            .and_then(|s| s.parse::<u64>().ok())
            .ok_or_else(|| bad("shard must be a decimal u64 string"))?;
        let quantization = match j.get("quant_bits") {
            Json::Null => None,
            q => {
                let bits =
                    q.as_usize().filter(|b| (1..=16).contains(b)).ok_or_else(|| {
                        bad("quant_bits must be null or an integer in 1..=16")
                    })?;
                Some(QuantizationMode::Bits(bits as u8).normalized())
            }
        };
        let capacity = match j.get("capacity") {
            Json::Null => None,
            c => Some(
                c.as_usize()
                    .filter(|&c| c >= 1)
                    .ok_or_else(|| bad("capacity must be null or >= 1"))?,
            ),
        };
        let next_epoch_id =
            j.get("next_epoch_id").as_usize().ok_or_else(|| bad("next_epoch_id missing"))? as u64;
        let rows_ingested =
            j.get("rows_ingested").as_usize().ok_or_else(|| bad("rows_ingested missing"))?;
        let epochs_j = j.get("epochs").as_arr().ok_or_else(|| bad("epochs missing"))?;
        if epochs_j.is_empty() {
            return Err(bad("a store holds at least one epoch"));
        }

        let mut epochs = Vec::with_capacity(epochs_j.len());
        for ej in epochs_j {
            let id = ej.get("id").as_usize().ok_or_else(|| bad("epoch id missing"))? as u64;
            let start_row =
                ej.get("start_row").as_usize().ok_or_else(|| bad("epoch start_row missing"))?;
            let span = match ej.get("span") {
                Json::Null => 1u64,
                s => s
                    .as_usize()
                    .filter(|&s| s >= 1)
                    .ok_or_else(|| bad("epoch span must be >= 1"))? as u64,
            };
            if span > 1 && version < 2 {
                return Err(bad("epoch spans require store format version >= 2"));
            }
            let art = SketchArtifact::from_json(ej.get("artifact"))?;
            epochs.push(RestoredEpoch { id, start_row, span, artifact: art });
        }
        SketchStore::restore(
            RestoredHeader { shard, quantization, capacity, compaction },
            next_epoch_id,
            rows_ingested,
            epochs,
        )
    }

    /// Rebuild a store from checkpoint parts — the shared tail of both the
    /// JSON and binary (CKMC) codecs. Validates every ring invariant:
    /// uniform operator and quantization across epochs, strictly
    /// increasing ids, non-decreasing start rows, `next_epoch_id` above
    /// every live id, the newest epoch accounting for `rows_ingested`,
    /// capacity respected — then re-derives and checksum-verifies the
    /// operator.
    pub(crate) fn restore(
        header: RestoredHeader,
        next_epoch_id: u64,
        rows_ingested: usize,
        parts: Vec<RestoredEpoch>,
    ) -> Result<SketchStore, ApiError> {
        let bad = |msg: &str| ApiError::Format(format!("store: {msg}"));
        let RestoredHeader { shard, quantization, capacity, compaction } = header;
        if parts.is_empty() {
            return Err(bad("a store holds at least one epoch"));
        }
        let mut spec: Option<OpSpec> = None;
        let mut epochs = VecDeque::with_capacity(parts.len());
        let mut last_id: Option<u64> = None;
        let mut last_start = 0usize;
        for RestoredEpoch { id, start_row, span, artifact: art } in parts {
            if span < 1 {
                return Err(bad("epoch span must be >= 1"));
            }
            if let Some(prev) = last_id {
                if id <= prev {
                    return Err(bad("epoch ids must be strictly increasing"));
                }
                if start_row < last_start {
                    return Err(bad("epoch start rows must be non-decreasing"));
                }
            }
            last_id = Some(id);
            last_start = start_row;
            match spec.as_ref() {
                None => {}
                Some(s) if *s == art.op => {}
                Some(s) => {
                    return Err(ApiError::OperatorMismatch {
                        left: s.describe(),
                        right: art.op.describe(),
                    })
                }
            }
            if spec.is_none() {
                spec = Some(art.op.clone());
            }
            let dither_seed = quantize::dither_seed_for_shard(art.op.seed, shard);
            let acc = match (quantization, art.quant) {
                (None, None) => EpochAcc::Dense(SketchAccumulator {
                    sum: art.sum,
                    count: art.count,
                    bounds: art.bounds,
                }),
                (Some(mode), Some(q)) if q.mode == mode => {
                    EpochAcc::Quantized(QuantizedAccumulator {
                        mode,
                        level_sums: q.level_sums,
                        count: art.count,
                        bounds: art.bounds,
                        dither_seed,
                    })
                }
                _ => return Err(bad("epoch quantization disagrees with the store header")),
            };
            epochs.push_back(EpochSketch { id, start_row, span, acc });
        }
        let spec = spec.expect("at least one epoch parsed");
        if last_id.expect("at least one epoch parsed") >= next_epoch_id {
            return Err(bad("next_epoch_id must exceed every epoch id"));
        }
        let newest = epochs.back().expect("at least one epoch parsed");
        if newest.start_row + newest.count() != rows_ingested {
            return Err(bad("rows_ingested disagrees with the newest epoch"));
        }
        if let Some(cap) = capacity {
            if epochs.len() > cap {
                return Err(bad("more surviving epochs than the declared capacity"));
            }
        }
        let op = spec.materialize()?; // checksum verified here, loudly
        let dither_seed = quantize::dither_seed_for_shard(spec.seed, shard);
        // Derive a non-zero generation from the persisted progress: any
        // store that ever ingested or rotated restores strictly past a
        // fresh store's generation 0, and a later checkpoint of the same
        // lineage restores past an earlier one — so generation-keyed
        // solve caches can never serve pre-checkpoint answers for a
        // restored store (see `SketchServer::restore` for the live-
        // replacement case).
        let generation = rows_ingested as u64 + next_epoch_id;
        Ok(SketchStore {
            spec,
            op,
            quantization,
            shard,
            dither_seed,
            capacity,
            compaction,
            epochs,
            next_epoch_id,
            rows_ingested,
            rows_reserved: rows_ingested, // reservations resume past everything ingested
            generation,
        })
    }

    /// Write the store as pretty-printed versioned JSON (atomically: a
    /// crash mid-checkpoint leaves the previous file intact).
    pub fn to_file<P: AsRef<Path>>(&self, path: P) -> Result<(), ApiError> {
        crate::util::fs::atomic_write(path, self.to_json().to_pretty().as_bytes())?;
        Ok(())
    }

    /// Write the store as a binary CKMC container (the compact codec; see
    /// [`crate::store::checkpoint`]). Full rewrite, atomic; for in-place
    /// epoch appends use [`crate::store::checkpoint::append_store_to_file`].
    pub fn to_binary_file<P: AsRef<Path>>(&self, path: P) -> Result<(), ApiError> {
        let image = crate::store::checkpoint::store_image(self);
        crate::util::fs::atomic_write(path, &image.to_bytes())?;
        Ok(())
    }

    /// Load a checkpointed store from either codec, sniffed by magic:
    /// `CKMC` means binary, anything else is parsed as JSON (operator
    /// checksum verified at load time in both).
    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<SketchStore, ApiError> {
        let bytes = std::fs::read(path)?;
        if crate::util::container::is_container(&bytes) {
            return crate::store::checkpoint::store_from_container(&bytes);
        }
        let text = std::str::from_utf8(&bytes)
            .map_err(|_| ApiError::Format("store file is neither CKMC nor UTF-8 JSON".into()))?;
        SketchStore::from_json(&Json::parse(text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::RadiusKind;
    use crate::testing::gen;
    use crate::util::rng::Rng;

    fn spec(seed: u64, m: usize, n: usize) -> OpSpec {
        OpSpec::derive(seed, RadiusKind::AdaptedRadius, 1.0, m, n).0
    }

    fn rows(rng: &mut Rng, n_rows: usize, n: usize) -> Vec<f64> {
        gen::mat_normal(rng, n_rows, n)
    }

    #[test]
    fn rotation_ids_and_eviction() {
        let mut store = SketchStore::create(spec(1, 8, 2), None, 0, Some(3)).unwrap();
        let mut rng = Rng::new(2);
        assert_eq!(store.epoch_count(), 1);
        assert_eq!(store.current_epoch_id(), 0);
        for e in 0..5u64 {
            store.ingest(&rows(&mut rng, 4, 2));
            let evicted = store.rotate();
            if e < 2 {
                assert!(evicted.is_empty(), "epoch {e}");
            } else {
                assert_eq!(evicted, vec![e - 2], "epoch {e}");
            }
        }
        assert_eq!(store.epoch_count(), 3);
        assert_eq!(store.oldest_epoch_id(), 3);
        assert_eq!(store.current_epoch_id(), 5);
        assert_eq!(store.rows_ingested(), 20);
        // newest epoch is empty, two sealed epochs of 4 rows survive
        assert_eq!(store.surviving_rows(), 8);
        let stats = store.epoch_stats();
        assert_eq!(stats.len(), 3);
        assert_eq!(stats[0], EpochStats { id: 3, start_row: 12, rows: 4, span: 1 });
        assert_eq!(stats[2], EpochStats { id: 5, start_row: 20, rows: 0, span: 1 });
    }

    #[test]
    fn window_clamps_and_rejects_zero() {
        let mut store = SketchStore::create(spec(3, 8, 2), None, 0, None).unwrap();
        let mut rng = Rng::new(4);
        store.ingest(&rows(&mut rng, 3, 2));
        store.rotate();
        store.ingest(&rows(&mut rng, 5, 2));
        assert!(matches!(
            store.window(0),
            Err(ApiError::InvalidConfig { field: "window", .. })
        ));
        assert_eq!(store.window(1).unwrap().count, 5);
        assert_eq!(store.window(2).unwrap().count, 8);
        // wider than the ring: clamps to everything surviving
        assert_eq!(store.window(99).unwrap(), store.window_all());
        assert_eq!(store.window_all().count, 8);
    }

    #[test]
    fn decayed_validates_lambda() {
        let store = SketchStore::create(spec(5, 8, 2), None, 0, None).unwrap();
        for bad in [-0.1, 1.5, f64::NAN, f64::INFINITY] {
            assert!(
                matches!(
                    store.decayed(bad),
                    Err(ApiError::InvalidConfig { field: "decay", .. })
                ),
                "lambda={bad}"
            );
        }
        assert!(store.decayed(0.5).is_ok());
    }

    #[test]
    fn create_rejects_zero_capacity() {
        assert!(matches!(
            SketchStore::create(spec(6, 8, 2), None, 0, Some(0)),
            Err(ApiError::InvalidConfig { field: "window", .. })
        ));
    }

    #[test]
    fn empty_store_snapshots_are_empty_artifacts() {
        let store = SketchStore::create(spec(7, 8, 3), None, 0, None).unwrap();
        let w = store.window_all();
        assert_eq!(w.count, 0);
        assert!(w.sum.re.iter().all(|&v| v == 0.0));
        assert_eq!(store.decayed(0.5).unwrap().count, 0);
    }

    #[test]
    fn json_roundtrip_dense_and_quantized() {
        for mode in [None, Some(QuantizationMode::OneBit), Some(QuantizationMode::Bits(4))] {
            let mut store = SketchStore::create(spec(8, 8, 3), mode, 2, Some(4)).unwrap();
            let mut rng = Rng::new(9);
            for _ in 0..3 {
                store.ingest(&rows(&mut rng, 7, 3));
                store.rotate();
            }
            store.ingest(&rows(&mut rng, 2, 3));
            let back = SketchStore::from_json(&Json::parse(&store.to_json().to_pretty()).unwrap())
                .unwrap();
            assert_eq!(back.spec, store.spec);
            assert_eq!(back.quantization, store.quantization);
            assert_eq!(back.shard, store.shard);
            assert_eq!(back.capacity, store.capacity);
            assert_eq!(back.rows_ingested, store.rows_ingested);
            assert_eq!(back.next_epoch_id, store.next_epoch_id);
            assert_eq!(back.epochs, store.epochs);
            assert_eq!(back.window_all(), store.window_all());
        }
    }

    #[test]
    fn resumed_quantized_ingest_is_bit_compatible() {
        // Checkpoint mid-stream, resume from disk, keep ingesting: the
        // resumed store must match an uninterrupted one bit for bit (the
        // dither row counter survives the roundtrip).
        let mut rng = Rng::new(11);
        let all = rows(&mut rng, 30, 3);
        let make = || {
            SketchStore::create(spec(12, 8, 3), Some(QuantizationMode::OneBit), 1, None).unwrap()
        };
        let mut uninterrupted = make();
        uninterrupted.ingest(&all[..12 * 3]);
        uninterrupted.rotate();
        uninterrupted.ingest(&all[12 * 3..]);

        let mut first = make();
        first.ingest(&all[..12 * 3]);
        first.rotate();
        let path = std::env::temp_dir().join(format!("ckm_store_{}.json", std::process::id()));
        first.to_file(&path).unwrap();
        let mut resumed = SketchStore::from_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        resumed.ingest(&all[12 * 3..]);

        assert_eq!(resumed.window_all(), uninterrupted.window_all());
        assert_eq!(resumed.epochs, uninterrupted.epochs);
    }

    #[test]
    fn two_phase_ingest_matches_synchronous_bit_for_bit() {
        // reserve → sketch_chunk → absorb (single producer, in order) must
        // reproduce the synchronous ingest path exactly, dense and 1-bit.
        let mut rng = Rng::new(21);
        let all = rows(&mut rng, 40, 3);
        for mode in [None, Some(QuantizationMode::OneBit)] {
            let mut sync = SketchStore::create(spec(22, 8, 3), mode, 1, None).unwrap();
            sync.ingest(&all[..25 * 3]);
            sync.rotate();
            sync.ingest(&all[25 * 3..]);

            let mut tp = SketchStore::create(spec(22, 8, 3), mode, 1, None).unwrap();
            let ctx = tp.sketch_context();
            let off = tp.reserve_rows(25);
            assert_eq!(off, 0);
            tp.absorb(ctx.sketch_chunk(&all[..25 * 3], off));
            tp.rotate();
            let off = tp.reserve_rows(15);
            assert_eq!(off, 25);
            tp.absorb(ctx.sketch_chunk(&all[25 * 3..], off));

            assert_eq!(tp.rows_ingested(), sync.rows_ingested());
            assert_eq!(tp.epochs, sync.epochs, "mode {mode:?}");
            assert_eq!(tp.window_all(), sync.window_all());
        }
    }

    #[test]
    fn out_of_order_absorbs_keep_reserved_dither_keys() {
        // Two chunks reserved in order but absorbed in REVERSE arrival
        // order: the dither keys must follow the reservation (rows 0..25
        // keep keys 0..25 even though they merge second). The pre-two-phase
        // implementation keyed dithers off rows_ingested at merge time and
        // fails this.
        let mut rng = Rng::new(31);
        let all = rows(&mut rng, 40, 3);
        let mode = Some(QuantizationMode::OneBit);
        let mut store = SketchStore::create(spec(23, 8, 3), mode, 0, None).unwrap();
        let ctx = store.sketch_context();
        let off_a = store.reserve_rows(25); // rows 0..25
        let off_b = store.reserve_rows(15); // rows 25..40
        let chunk_a = ctx.sketch_chunk(&all[..25 * 3], off_a);
        let chunk_b = ctx.sketch_chunk(&all[25 * 3..], off_b);
        store.absorb(chunk_b); // B lands first
        store.absorb(chunk_a);
        assert_eq!(store.rows_ingested(), 40);

        let mut reference = SketchStore::create(spec(23, 8, 3), mode, 0, None).unwrap();
        reference.ingest(&all);
        // Integer merges commute, and the keys came from the reservation:
        // arrival order cannot change a single bit.
        assert_eq!(store.window_all(), reference.window_all());
    }

    #[test]
    fn absorb_lands_in_the_epoch_current_at_merge_time() {
        let mut rng = Rng::new(41);
        let all = rows(&mut rng, 20, 2);
        let mut store = SketchStore::create(spec(24, 8, 2), None, 0, None).unwrap();
        let ctx = store.sketch_context();
        let off = store.reserve_rows(20);
        let chunk = ctx.sketch_chunk(&all, off);
        store.rotate(); // rotation interleaves between reserve and absorb
        store.absorb(chunk);
        // rows belong to the epoch current when the merge landed
        let stats = store.epoch_stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].rows, 0);
        assert_eq!(stats[1].rows, 20);
        assert_eq!(store.rows_ingested(), 20);
        // the at-rest serialization invariants still hold
        let back =
            SketchStore::from_json(&Json::parse(&store.to_json().to_pretty()).unwrap()).unwrap();
        assert_eq!(back.epochs, store.epochs);
        assert_eq!(back.rows_ingested(), 20);
    }

    #[test]
    #[should_panic(expected = "chunk sketch kind")]
    fn absorb_rejects_mismatched_chunk_kind() {
        let mut rng = Rng::new(51);
        let all = rows(&mut rng, 4, 2);
        let dense = SketchStore::create(spec(25, 8, 2), None, 0, None).unwrap();
        let mut quant =
            SketchStore::create(spec(25, 8, 2), Some(QuantizationMode::OneBit), 0, None).unwrap();
        let chunk = dense.sketch_context().sketch_chunk(&all, 0);
        quant.absorb(chunk);
    }

    #[test]
    fn generation_bumps_on_every_mutation_and_survives_restore() {
        let mut store =
            SketchStore::create(spec(61, 8, 2), Some(QuantizationMode::OneBit), 0, None).unwrap();
        let mut rng = Rng::new(62);
        assert_eq!(store.generation(), 0);
        store.ingest(&rows(&mut rng, 4, 2));
        assert_eq!(store.generation(), 1);
        store.rotate();
        assert_eq!(store.generation(), 2);
        let ctx = store.sketch_context();
        let off = store.reserve_rows(3);
        store.absorb(ctx.sketch_chunk(&rows(&mut rng, 3, 2), off));
        assert_eq!(store.generation(), 3);
        // A restored store derives a non-zero generation from its progress
        // (7 rows + 2 epoch ids here): a cache keyed on generation can
        // never mistake it for the fresh-store generation 0.
        let back =
            SketchStore::from_json(&Json::parse(&store.to_json().to_pretty()).unwrap()).unwrap();
        assert_eq!(back.generation(), 9);
        assert!(back.generation() > 0);
        // And the floor bump moves strictly past any live generation.
        let mut back2 = back.clone();
        back2.bump_generation_past(1000);
        assert_eq!(back2.generation(), 1001);
        back2.bump_generation_past(5); // already past: untouched
        assert_eq!(back2.generation(), 1001);
    }

    #[test]
    fn exponential_compaction_keeps_log_buckets_and_exact_windows() {
        for mode in [None, Some(QuantizationMode::OneBit)] {
            let make = |policy| {
                SketchStore::create(spec(71, 8, 3), mode, 0, None)
                    .unwrap()
                    .with_compaction(policy)
            };
            let mut plain = make(CompactionPolicy::None);
            let mut packed = make(CompactionPolicy::Exponential);
            let mut rng = Rng::new(72);
            let n_epochs = 64usize;
            for e in 0..n_epochs {
                let chunk = rows(&mut rng, 3 + (e % 5), 3);
                plain.ingest(&chunk);
                packed.ingest(&chunk);
                plain.rotate();
                packed.rotate();
            }
            assert_eq!(plain.epoch_count(), n_epochs + 1);
            // Exponential histogram: at most 2 buckets per power-of-two
            // span ⇒ O(log E) buckets for E sealed epochs.
            assert!(
                packed.epoch_count() <= 2 * ((n_epochs as f64).log2().ceil() as usize + 1) + 1,
                "{} buckets for {} epochs",
                packed.epoch_count(),
                n_epochs
            );
            let stats = packed.epoch_stats();
            // spans are powers of two, non-increasing toward the newest end
            for w in stats.windows(2) {
                assert!(w[0].span >= w[1].span, "{stats:?}");
                assert!(w[0].span.is_power_of_two());
            }
            // span total accounts for every original epoch
            assert_eq!(stats.iter().map(|s| s.span).sum::<u64>(), n_epochs as u64 + 1);
            // ids stay strictly increasing
            for w in stats.windows(2) {
                assert!(w[0].id < w[1].id);
            }
            // the full-ring merge covers the same rows; quantized merges
            // are integer-exact, so the artifact matches bit for bit
            assert_eq!(packed.surviving_rows(), plain.surviving_rows());
            let (pw, cw) = (plain.window_all(), packed.window_all());
            assert_eq!(pw.count, cw.count);
            assert_eq!(pw.bounds, cw.bounds);
            match mode {
                Some(_) => assert_eq!(pw, cw),
                None => assert!(pw.sum.max_abs_diff(&cw.sum) <= 1e-9 * pw.count as f64),
            }
        }
    }

    #[test]
    fn compacted_windows_widen_to_bucket_boundaries() {
        let mut store = SketchStore::create(spec(73, 8, 2), None, 0, None)
            .unwrap()
            .with_compaction(CompactionPolicy::Exponential);
        let mut rng = Rng::new(74);
        for _ in 0..16 {
            store.ingest(&rows(&mut rng, 2, 2));
            store.rotate();
        }
        // window(1) is always exactly the (never-compacted) current epoch
        assert_eq!(store.window(1).unwrap().count, 0);
        // a window over e original epochs covers at least e·2 rows and
        // lands on a bucket boundary (a multiple of 2 rows here)
        for e in 1..=16usize {
            let w = store.window(e).unwrap();
            assert!(w.count >= (e.saturating_sub(1)) * 2, "e={e} count={}", w.count);
            assert_eq!(w.count % 2, 0);
        }
        assert_eq!(store.window(99).unwrap().count, 32);
    }

    #[test]
    fn compacted_store_serialization_roundtrips() {
        let mut store =
            SketchStore::create(spec(75, 8, 2), Some(QuantizationMode::Bits(2)), 1, None)
                .unwrap()
                .with_compaction(CompactionPolicy::Exponential);
        let mut rng = Rng::new(76);
        for _ in 0..9 {
            store.ingest(&rows(&mut rng, 3, 2));
            store.rotate();
        }
        assert!(store.epoch_stats().iter().any(|s| s.span > 1));
        let j = store.to_json();
        assert_eq!(j.get("version").as_usize(), Some(2));
        let back = SketchStore::from_json(&Json::parse(&j.to_pretty()).unwrap()).unwrap();
        assert_eq!(back.compaction(), CompactionPolicy::Exponential);
        assert_eq!(back.epochs, store.epochs);
        assert_eq!(back.window_all(), store.window_all());
        // an uncompacted ring still writes the version-1 schema
        let plain = SketchStore::create(spec(75, 8, 2), None, 0, None).unwrap();
        assert_eq!(plain.to_json().get("version").as_usize(), Some(1));
        // spans in a version-1 file are rejected
        let mut j1 = store.to_json();
        if let Json::Obj(o) = &mut j1 {
            o.insert("version".to_string(), Json::Num(1.0));
        }
        assert!(SketchStore::from_json(&j1).is_err());
    }

    #[test]
    fn from_json_rejects_corruption() {
        let mut store = SketchStore::create(spec(13, 8, 2), None, 0, None).unwrap();
        let mut rng = Rng::new(14);
        store.ingest(&rows(&mut rng, 4, 2));
        let good = store.to_json();
        // wrong format tag
        let mut j = good.clone();
        if let Json::Obj(o) = &mut j {
            o.insert("format".to_string(), Json::Str("nope".into()));
        }
        assert!(SketchStore::from_json(&j).is_err());
        // future version
        let mut j = good.clone();
        if let Json::Obj(o) = &mut j {
            o.insert("version".to_string(), Json::Num(99.0));
        }
        assert!(matches!(
            SketchStore::from_json(&j),
            Err(ApiError::UnsupportedVersion { found: 99, .. })
        ));
        // rows_ingested out of step with the newest epoch
        let mut j = good;
        if let Json::Obj(o) = &mut j {
            o.insert("rows_ingested".to_string(), Json::Num(17.0));
        }
        assert!(SketchStore::from_json(&j).is_err());
    }
}
