//! L4 store: time-windowed sketch serving over unbounded streams.
//!
//! The sketch is a constant-size, exactly-mergeable summary whose solve
//! cost is independent of the number of points — which makes it the ideal
//! *state object* for a long-running service ingesting an unbounded
//! stream. What the plain accumulator lacks is **time**: once a point is
//! absorbed it can never be aged out, so "cluster the last hour" requires
//! revisiting raw data. This module adds time the only way the sketch
//! algebra allows it — by *bucketing*, never by subtraction:
//!
//! - [`SketchStore`] — a ring of per-epoch sketches (dense or quantized).
//!   [`SketchStore::ingest`] feeds the newest epoch, [`SketchStore::rotate`]
//!   seals it and opens the next (evicting the oldest bucket once the
//!   configured capacity is exceeded), [`SketchStore::window`] merges the
//!   newest `e` epochs into a [`crate::api::SketchArtifact`] — *exactly*,
//!   because dense sums and integer level sums are both associative and
//!   eviction is bucket drop, never subtraction error — and
//!   [`SketchStore::decayed`] builds an exponentially-weighted sketch
//!   (per-epoch scalar weights on sum and count: a weighted empirical
//!   characteristic function, so CLOMPR consumes it unchanged).
//! - [`SketchServer`] — the concurrent wrapper: any number of producer
//!   threads push rows through per-producer [`IngestSession`]s (local
//!   [`crate::coordinator::batcher::Batcher`] chunking; each full chunk
//!   runs two-phase ingest — reserve the row range under a short lock,
//!   sketch on the producer's thread with no lock held via
//!   [`SketchContext`], merge exactly under a second short lock) while
//!   snapshot-solve requests
//!   ([`SketchServer::solve_window`] / [`SketchServer::solve_decayed`])
//!   are answered from a generation-keyed solve cache and never hold the
//!   store lock during the CLOMPR decode.
//!
//! - [`ShardedStore`] — N key-sharded stores behind N independent locks
//!   (producer → shard by FNV-1a of the producer id; shard `i` salts its
//!   dither stream with `base_shard + i`), with *exact* cross-shard merged
//!   window/decayed snapshots taken under an all-locks consistent cut.
//!   This is the state object behind the `ckmd` daemon
//!   ([`crate::service`]).
//!
//! Long-lived rings can bound their bucket count with
//! [`CompactionPolicy::Exponential`]: sealed epochs collapse into
//! power-of-two spans (at most two buckets per span), keeping `O(log E)`
//! buckets while window merges stay exact (they widen to bucket
//! boundaries, never split one).
//!
//! A whole store serializes through two codecs sharing one restore path:
//! versioned JSON (the debug codec; epoch entries are ordinary format-v2
//! artifacts) and the binary CKMC container ([`checkpoint`] — compact,
//! per-section checksummed, append-without-rewrite for the `ckmd` restart
//! WAL). [`SketchStore::from_file`] / [`ShardedStore::from_file`] sniff
//! the codec by magic, so a service can checkpoint and resume from either
//! — including the quantized dither row counter, which keeps resumed
//! ingest bit-compatible with an uninterrupted run. A [`ShardedStore`]
//! checkpoints all shards into one `ckm-store-set` document.
//!
//! Entry points live on the facade: `Ckm::builder().window(epochs)` sets
//! the ring capacity, `.decay(lambda)` the default decay, and
//! [`crate::api::Ckm::store`] / [`crate::api::Ckm::server`] construct the
//! pieces with the builder's validated operator provenance.

pub mod checkpoint;
pub mod ring;
pub mod server;
pub mod sharded;

pub use checkpoint::{
    append_store_set_to_file, append_store_to_file, convert_file, load_store_set_wal,
    AppendStats, Codec, ConvertReport, DocKind,
};
pub use ring::{
    ChunkSketch, CompactionPolicy, EpochStats, SketchContext, SketchStore, STORE_FORMAT_VERSION,
};
pub use server::{IngestSession, ServerStats, SketchServer};
pub use sharded::{ShardStats, ShardedStore, STORE_SET_FORMAT_VERSION};
