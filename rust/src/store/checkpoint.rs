//! Binary (CKMC) checkpoint codec for stores and store sets, plus the
//! append-without-rewrite path the `ckmd` daemon uses as a restart WAL.
//!
//! Layout (see [`crate::util::container`] for the envelope): every store
//! document is one container whose leading `SEC_META` section carries the
//! doc kind, the operator spec and the ring configuration; each surviving
//! epoch is its own `SEC_EPOCH_DENSE` / `SEC_EPOCH_QUANT` section (tag =
//! epoch id, payload = shard index + id + start_row + span + artifact
//! body); the mutable counters (`next_epoch_id`, `rows_ingested` per
//! shard) live in the footer's state blob, which every append rewrites.
//!
//! [`append_store_to_file`] turns that layout into a WAL: sealed epochs
//! re-encode byte-identically, so their existing sections are matched by
//! (kind, tag, len, checksum) and *kept* — only changed sections (the
//! open epoch, freshly sealed epochs, compacted buckets) are appended and
//! the footer rewritten. Bytes of kept sections are never touched, so a
//! long-lived checkpoint file grows by roughly one epoch per rotation
//! instead of being rewritten wholesale.

use super::ring::{CompactionPolicy, RestoredEpoch, RestoredHeader, SketchStore};
use super::sharded::ShardedStore;
use crate::api::artifact::binary::{
    decode_artifact_body, decode_spec, encode_artifact_body, encode_spec, open_meta,
    DOC_ARTIFACT, DOC_STORE, DOC_STORE_SET, SEC_EPOCH_DENSE, SEC_EPOCH_QUANT, SEC_META,
};
use crate::api::{ApiError, OpSpec, QuantizationMode, SketchArtifact};
use crate::util::container::{
    append_sections_recoverable, is_container, recover_valid_prefix, ContainerError,
    ContainerImage, ContainerReader, SectionEntry,
};
use crate::util::digest::Fnv1a;
use crate::util::framing::{ByteReader, ByteWriter};
use crate::util::json::Json;
use std::path::Path;

fn bad(msg: &str) -> ApiError {
    ApiError::Format(format!("checkpoint: {msg}"))
}

// -- shared header / state codecs -----------------------------------------

/// Per-store configuration block inside a meta section: spec + quant bits
/// (0 = dense) + shard salt + capacity (0 = unbounded) + compaction code.
fn encode_store_header(w: &mut ByteWriter, store: &SketchStore) {
    encode_spec(w, store.spec());
    w.u8(store.quantization().map(|m| m.bits() as u8).unwrap_or(0));
    w.u64(store.shard());
    w.u64(store.capacity().map(|c| c as u64).unwrap_or(0));
    w.u8(match store.compaction() {
        CompactionPolicy::None => 0,
        CompactionPolicy::Exponential => 1,
    });
}

fn decode_store_header(r: &mut ByteReader) -> Result<(OpSpec, RestoredHeader), ApiError> {
    let spec = decode_spec(r)?;
    let quantization = match r.u8()? {
        0 => None,
        bits @ 1..=16 => Some(QuantizationMode::Bits(bits).normalized()),
        other => return Err(bad(&format!("quant bits {other} out of range 0..=16"))),
    };
    let shard = r.u64()?;
    let capacity = match r.usize_capped(u64::MAX as usize >> 1, "store.capacity")? {
        0 => None,
        c => Some(c),
    };
    let compaction = match r.u8()? {
        0 => CompactionPolicy::None,
        1 => CompactionPolicy::Exponential,
        other => return Err(bad(&format!("unknown compaction code {other}"))),
    };
    Ok((spec, RestoredHeader { shard, quantization, capacity, compaction }))
}

/// The footer state blob: shard count + per-shard mutable counters. This
/// is the only part of a store document an append rewrites, so the whole
/// ring's progress survives without touching any section bytes.
fn encode_state(shards: &[&SketchStore]) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.u32(shards.len() as u32);
    for s in shards {
        w.u64(s.next_epoch_id());
        w.u64(s.rows_ingested() as u64);
    }
    w.into_vec()
}

fn decode_state(bytes: &[u8], expect: usize) -> Result<Vec<(u64, usize)>, ApiError> {
    let mut r = ByteReader::new(bytes);
    let n = r.u32()? as usize;
    if n != expect {
        return Err(bad(&format!("state carries {n} shard counters, meta declares {expect}")));
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let next_epoch_id = r.u64()?;
        let rows_ingested = r.usize_capped(u64::MAX as usize >> 1, "state.rows_ingested")?;
        out.push((next_epoch_id, rows_ingested));
    }
    r.finish().map_err(ApiError::from)?;
    Ok(out)
}

// -- epoch sections --------------------------------------------------------

/// Encode every surviving epoch of one store as `(kind, tag, payload)`
/// sections, oldest first. Deterministic: a sealed epoch re-encodes to the
/// same bytes on every call, which is what lets appends keep old sections
/// by checksum instead of decoding them.
fn epoch_sections(shard_idx: u32, store: &SketchStore) -> Vec<(u8, u64, Vec<u8>)> {
    store
        .epoch_stats()
        .iter()
        .zip(store.epoch_artifacts())
        .map(|(st, art)| {
            let mut w = ByteWriter::new();
            w.u32(shard_idx);
            w.u64(st.id);
            w.u64(st.start_row as u64);
            w.u64(st.span);
            encode_artifact_body(&mut w, &art);
            let kind = if art.quant.is_some() { SEC_EPOCH_QUANT } else { SEC_EPOCH_DENSE };
            (kind, st.id, w.into_vec())
        })
        .collect()
}

/// Decode an epoch payload after its leading shard index has been read.
fn decode_epoch_body(
    r: &mut ByteReader,
    entry_kind: u8,
    spec: &OpSpec,
) -> Result<RestoredEpoch, ApiError> {
    let id = r.u64()?;
    let start_row = r.usize_capped(u64::MAX as usize >> 1, "epoch.start_row")?;
    let span = r.u64()?;
    let artifact = decode_artifact_body(r, spec)?;
    r.finish().map_err(ApiError::from)?;
    let expect = if artifact.quant.is_some() { SEC_EPOCH_QUANT } else { SEC_EPOCH_DENSE };
    if entry_kind != expect {
        return Err(bad("epoch section kind disagrees with its payload"));
    }
    Ok(RestoredEpoch { id, start_row, span, artifact })
}

fn epoch_kind_ok(kind: u8) -> Result<(), ApiError> {
    if kind == SEC_EPOCH_DENSE || kind == SEC_EPOCH_QUANT {
        Ok(())
    } else {
        Err(bad(&format!("unexpected section kind {kind} in a store checkpoint")))
    }
}

// -- single store ----------------------------------------------------------

/// Build the full container image of one store: meta, every epoch,
/// counters in the state blob.
pub(crate) fn store_image(store: &SketchStore) -> ContainerImage {
    let mut img = ContainerImage::new(encode_state(&[store]));
    let mut meta = ByteWriter::new();
    meta.u8(DOC_STORE);
    encode_store_header(&mut meta, store);
    img.push_section(SEC_META, 0, meta.into_vec());
    for (kind, tag, payload) in epoch_sections(0, store) {
        img.push_section(kind, tag, payload);
    }
    img
}

/// Decode a single-store container, re-validating every ring invariant
/// through [`SketchStore::restore`] (operator checksum included).
pub(crate) fn store_from_container(bytes: &[u8]) -> Result<SketchStore, ApiError> {
    let c = ContainerReader::parse(bytes)?;
    let (doc, mut meta) = open_meta(&c)?;
    if doc != DOC_STORE {
        return Err(bad(&format!("container holds doc kind {doc}, not a single-store checkpoint")));
    }
    let (spec, header) = decode_store_header(&mut meta)?;
    meta.finish().map_err(ApiError::from)?;
    let (next_epoch_id, rows_ingested) = decode_state(c.state(), 1)?[0];
    let mut parts = Vec::with_capacity(c.entries().len().saturating_sub(1));
    for i in 1..c.entries().len() {
        let kind = c.entries()[i].kind;
        epoch_kind_ok(kind)?;
        let mut r = ByteReader::new(c.section(i)?);
        if r.u32()? != 0 {
            return Err(bad("single-store checkpoint carries a nonzero shard index"));
        }
        parts.push(decode_epoch_body(&mut r, kind, &spec)?);
    }
    SketchStore::restore(header, next_epoch_id, rows_ingested, parts)
}

// -- sharded store set -----------------------------------------------------

/// Build the container image of a whole store set (one consistent
/// snapshot of every shard, e.g. from [`ShardedStore::snapshot`]).
pub(crate) fn store_set_image(base_shard: u64, shards: &[SketchStore]) -> ContainerImage {
    let refs: Vec<&SketchStore> = shards.iter().collect();
    let mut img = ContainerImage::new(encode_state(&refs));
    let mut meta = ByteWriter::new();
    meta.u8(DOC_STORE_SET);
    meta.u64(base_shard);
    meta.u32(shards.len() as u32);
    for s in shards {
        encode_store_header(&mut meta, s);
    }
    img.push_section(SEC_META, 0, meta.into_vec());
    for (i, s) in shards.iter().enumerate() {
        for (kind, tag, payload) in epoch_sections(i as u32, s) {
            img.push_section(kind, tag, payload);
        }
    }
    img
}

/// Decode a store-set container: per-shard headers from the meta section,
/// epoch sections routed to their shard by the leading index, then the
/// usual restore + uniform-provenance validation.
pub(crate) fn store_set_from_container(bytes: &[u8]) -> Result<ShardedStore, ApiError> {
    let c = ContainerReader::parse(bytes)?;
    let (doc, mut meta) = open_meta(&c)?;
    if doc != DOC_STORE_SET {
        return Err(bad(&format!("container holds doc kind {doc}, not a store-set checkpoint")));
    }
    let base_shard = meta.u64()?;
    let n_shards = meta.u32()? as usize;
    if n_shards == 0 || n_shards > 1 << 20 {
        return Err(bad(&format!("implausible shard count {n_shards}")));
    }
    let mut headers = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        headers.push(decode_store_header(&mut meta)?);
    }
    meta.finish().map_err(ApiError::from)?;
    let state = decode_state(c.state(), n_shards)?;
    let mut parts: Vec<Vec<RestoredEpoch>> = vec![Vec::new(); n_shards];
    for i in 1..c.entries().len() {
        let kind = c.entries()[i].kind;
        epoch_kind_ok(kind)?;
        let mut r = ByteReader::new(c.section(i)?);
        let shard_idx = r.u32()? as usize;
        if shard_idx >= n_shards {
            return Err(bad(&format!("epoch section addresses shard {shard_idx} of {n_shards}")));
        }
        let ep = decode_epoch_body(&mut r, kind, &headers[shard_idx].0)?;
        parts[shard_idx].push(ep);
    }
    let mut stores = Vec::with_capacity(n_shards);
    for (i, ((_, header), (next_epoch_id, rows_ingested))) in
        headers.into_iter().zip(state).enumerate()
    {
        stores.push(
            SketchStore::restore(header, next_epoch_id, rows_ingested, std::mem::take(&mut parts[i]))
                .map_err(|e| match e {
                    ApiError::Format(msg) => ApiError::Format(format!("shard {i}: {msg}")),
                    other => other,
                })?,
        );
    }
    ShardedStore::from_stores(base_shard, stores)
}

// -- append-without-rewrite (the ckmd restart WAL) -------------------------

/// What one [`append_store_to_file`] call did to the file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AppendStats {
    /// Sections carried over untouched from the existing table.
    pub kept: usize,
    /// Sections whose payload bytes were appended this call.
    pub appended: usize,
    /// True when the file was (re)written wholesale instead of appended:
    /// it was missing, or its tail was torn by a crashed previous append.
    pub rewritten: bool,
}

/// Checkpoint one store into `path` by appending: sections whose fresh
/// encoding matches an existing table entry (kind, tag, len, FNV-1a) are
/// kept verbatim — their bytes are never rewritten — and only changed
/// sections (at minimum the open epoch) plus a fresh footer go to disk.
///
/// A missing file becomes a full atomic write; a torn tail (crashed
/// previous append) is healed the same way. A file that parses but whose
/// meta disagrees with this store's configuration is *not* overwritten —
/// that is a typed error, because it means the path belongs to a
/// different store lineage.
pub fn append_store_to_file<P: AsRef<Path>>(
    store: &SketchStore,
    path: P,
) -> Result<AppendStats, ApiError> {
    let path = path.as_ref();
    let mut meta = ByteWriter::new();
    meta.u8(DOC_STORE);
    encode_store_header(&mut meta, store);
    let meta_payload = meta.into_vec();
    let state = encode_state(&[store]);
    let fresh = epoch_sections(0, store);

    let rewrite = |stats_appended: usize| -> Result<AppendStats, ApiError> {
        store.to_binary_file(path)?;
        Ok(AppendStats { kept: 0, appended: stats_appended, rewritten: true })
    };

    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return rewrite(fresh.len() + 1);
        }
        Err(e) => return Err(e.into()),
    };
    let reader = match ContainerReader::parse(&bytes) {
        Ok(r) => r,
        // A torn tail from a crashed append parses as a typed error; the
        // store in hand *is* the recovery state, so heal by full rewrite.
        Err(ContainerError::Io(e)) => return Err(e.into()),
        Err(_) => return rewrite(fresh.len() + 1),
    };
    let old_entries = reader.entries();
    if old_entries.first().map(|e| e.kind) != Some(SEC_META)
        || reader.section(0)? != &meta_payload[..]
    {
        return Err(bad("existing container belongs to a different store or configuration"));
    }

    let mut kept: Vec<SectionEntry> = vec![old_entries[0].clone()];
    let mut new_sections = Vec::new();
    let mut max_kept_id: Option<u64> = None;
    for (kind, tag, payload) in fresh {
        let checksum = Fnv1a::hash(&payload);
        let hit = old_entries[1..].iter().find(|e| {
            e.kind == kind && e.tag == tag && e.len == payload.len() as u64 && e.checksum == checksum
        });
        match hit {
            Some(e) => {
                kept.push(e.clone());
                max_kept_id = Some(tag);
            }
            None => new_sections.push((kind, tag, payload)),
        }
    }
    // The appended table lists kept sections before new ones, and restore
    // requires strictly increasing epoch ids in table order. If an *old*
    // epoch changed (a compaction merge rewrote a bucket below a kept
    // one), appending would put it out of order — heal by full rewrite.
    if let Some(max_kept) = max_kept_id {
        if new_sections.iter().any(|(_, tag, _)| *tag <= max_kept) {
            return rewrite(new_sections.len());
        }
    }
    let stats = AppendStats {
        kept: kept.len(),
        appended: new_sections.len(),
        rewritten: false,
    };
    drop(reader);
    drop(bytes);
    crate::util::container::append_sections(path, &state, &kept, &new_sections)?;
    Ok(stats)
}

// -- store-set WAL (the ckmd crash-recovery log) ---------------------------

/// Truncate `path` to exactly `len` bytes (WAL torn-tail healing).
fn truncate_file(path: &Path, len: u64) -> std::io::Result<()> {
    let f = std::fs::OpenOptions::new().write(true).open(path)?;
    f.set_len(len)?;
    f.sync_all()
}

/// Checkpoint a whole store set into `path` as a **crash-recoverable
/// WAL append**: unchanged epoch sections are kept by checksum match,
/// changed ones are appended, and — unlike [`append_store_to_file`] —
/// the superseded footer is left in place
/// ([`append_sections_recoverable`]), so a `kill -9` at any instant
/// leaves the previous append fully loadable. A torn tail found on entry
/// is healed to its longest valid prefix and the append continues on
/// top of the recovered state; a file from a different store lineage is
/// a typed error, never overwritten.
pub fn append_store_set_to_file<P: AsRef<Path>>(
    set: &ShardedStore,
    path: P,
) -> Result<AppendStats, ApiError> {
    let path = path.as_ref();
    let shards = set.snapshot();
    let base_shard = set.base_shard();
    let mut meta = ByteWriter::new();
    meta.u8(DOC_STORE_SET);
    meta.u64(base_shard);
    meta.u32(shards.len() as u32);
    for s in &shards {
        encode_store_header(&mut meta, s);
    }
    let meta_payload = meta.into_vec();
    let refs: Vec<&SketchStore> = shards.iter().collect();
    let state = encode_state(&refs);
    let mut fresh: Vec<(usize, (u8, u64, Vec<u8>))> = Vec::new();
    for (i, s) in shards.iter().enumerate() {
        for sec in epoch_sections(i as u32, s) {
            fresh.push((i, sec));
        }
    }

    let rewrite = |appended: usize| -> Result<AppendStats, ApiError> {
        let img = store_set_image(base_shard, &shards);
        crate::util::fs::atomic_write(path, &img.to_bytes())?;
        Ok(AppendStats { kept: 0, appended, rewritten: true })
    };

    let mut bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return rewrite(fresh.len() + 1);
        }
        Err(e) => return Err(e.into()),
    };
    match ContainerReader::parse(&bytes) {
        Ok(_) => {}
        Err(ContainerError::Io(e)) => return Err(e.into()),
        // Torn tail from a crashed append: the recoverable-append
        // invariant guarantees the previous append survives as a valid
        // prefix — truncate back to it and append on top.
        Err(_) => match recover_valid_prefix(&bytes) {
            Some(len) => {
                truncate_file(path, len as u64)?;
                bytes.truncate(len);
            }
            None => return rewrite(fresh.len() + 1),
        },
    }
    let reader = ContainerReader::parse(&bytes).expect("prefix validated above");
    let old_entries = reader.entries();
    if old_entries.first().map(|e| e.kind) != Some(SEC_META)
        || reader.section(0)? != &meta_payload[..]
    {
        return Err(bad("existing container belongs to a different store set or configuration"));
    }

    let mut kept: Vec<SectionEntry> = vec![old_entries[0].clone()];
    let mut new_sections: Vec<(u8, u64, Vec<u8>)> = Vec::new();
    let mut new_shards: Vec<usize> = Vec::new();
    let mut max_kept_id: Vec<Option<u64>> = vec![None; shards.len()];
    for (shard_idx, (kind, tag, payload)) in fresh {
        let checksum = Fnv1a::hash(&payload);
        let hit = old_entries[1..].iter().find(|e| {
            e.kind == kind && e.tag == tag && e.len == payload.len() as u64 && e.checksum == checksum
        });
        match hit {
            Some(e) => {
                kept.push(e.clone());
                max_kept_id[shard_idx] = Some(tag);
            }
            None => {
                new_sections.push((kind, tag, payload));
                new_shards.push(shard_idx);
            }
        }
    }
    // Same ordering guard as the single-store append, per shard: kept
    // sections precede appended ones in the table, and restore demands
    // ascending epoch ids per shard in table order.
    let out_of_order = new_sections
        .iter()
        .zip(&new_shards)
        .any(|((_, tag, _), &sh)| max_kept_id[sh].is_some_and(|m| *tag <= m));
    if out_of_order {
        return rewrite(new_sections.len());
    }
    let stats = AppendStats {
        kept: kept.len(),
        appended: new_sections.len(),
        rewritten: false,
    };
    drop(reader);
    drop(bytes);
    append_sections_recoverable(path, &state, &kept, &new_sections)?;
    Ok(stats)
}

/// Load a store set from a WAL file written by
/// [`append_store_set_to_file`], healing a torn tail. Returns the
/// restored set and whether healing happened (`true` = the file was
/// truncated back to its last valid append). A file with no valid
/// prefix at all surfaces the original typed decode error.
pub fn load_store_set_wal<P: AsRef<Path>>(path: P) -> Result<(ShardedStore, bool), ApiError> {
    let path = path.as_ref();
    let bytes = std::fs::read(path)?;
    match store_set_from_container(&bytes) {
        Ok(set) => Ok((set, false)),
        Err(ApiError::Io(e)) => Err(e.into()),
        Err(first) => {
            let len = recover_valid_prefix(&bytes).ok_or(first)?;
            let set = store_set_from_container(&bytes[..len])?;
            truncate_file(path, len as u64)?;
            Ok((set, true))
        }
    }
}

// -- document detection & conversion (the `ckm convert` entry point) -------

/// What a checkpoint file holds, independent of codec.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DocKind {
    /// A standalone sketch artifact (`ckm-sketch`).
    Artifact,
    /// A single epoch-ring store (`ckm-store`).
    Store,
    /// A sharded store set (`ckm-store-set`).
    StoreSet,
}

impl DocKind {
    pub fn name(self) -> &'static str {
        match self {
            DocKind::Artifact => "sketch artifact",
            DocKind::Store => "store",
            DocKind::StoreSet => "store set",
        }
    }
}

/// Which codec a checkpoint file uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    Json,
    Binary,
}

impl Codec {
    pub fn name(self) -> &'static str {
        match self {
            Codec::Json => "json",
            Codec::Binary => "ckmc",
        }
    }
}

/// Sniff a checkpoint's codec (by magic) and document kind (meta doc byte
/// for binary, `format` tag for JSON) without decoding the payload.
pub fn detect(bytes: &[u8]) -> Result<(DocKind, Codec), ApiError> {
    if is_container(bytes) {
        let c = ContainerReader::parse(bytes)?;
        let (doc, _) = open_meta(&c)?;
        let kind = match doc {
            DOC_ARTIFACT => DocKind::Artifact,
            DOC_STORE => DocKind::Store,
            DOC_STORE_SET => DocKind::StoreSet,
            other => return Err(bad(&format!("unknown container doc kind {other}"))),
        };
        return Ok((kind, Codec::Binary));
    }
    let text = std::str::from_utf8(bytes)
        .map_err(|_| bad("file is neither a CKMC container nor UTF-8 JSON"))?;
    let j = Json::parse(text)?;
    let kind = match j.get("format").as_str() {
        Some("ckm-sketch") => DocKind::Artifact,
        Some("ckm-store") => DocKind::Store,
        Some("ckm-store-set") => DocKind::StoreSet,
        Some(other) => return Err(bad(&format!("unknown format tag {other:?}"))),
        None => return Err(bad("JSON file carries no format tag")),
    };
    Ok((kind, Codec::Json))
}

/// What [`convert_file`] did.
#[derive(Clone, Debug)]
pub struct ConvertReport {
    pub doc: DocKind,
    pub from: Codec,
    pub to: Codec,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

/// Convert a checkpoint file to the *other* codec (JSON ⇄ CKMC),
/// preserving the document kind. The input is fully decoded and
/// re-validated (operator checksum included) before the output is
/// written atomically, so a convert can never launder a corrupt file.
pub fn convert_file<P: AsRef<Path>, Q: AsRef<Path>>(
    input: P,
    output: Q,
) -> Result<ConvertReport, ApiError> {
    let input = input.as_ref();
    let output = output.as_ref();
    let bytes = std::fs::read(input)?;
    let (doc, from) = detect(&bytes)?;
    let to = match from {
        Codec::Json => Codec::Binary,
        Codec::Binary => Codec::Json,
    };
    match (doc, to) {
        (DocKind::Artifact, Codec::Binary) => {
            SketchArtifact::from_file(input)?.to_binary_file(output)?
        }
        (DocKind::Artifact, Codec::Json) => SketchArtifact::from_file(input)?.to_file(output)?,
        (DocKind::Store, Codec::Binary) => SketchStore::from_file(input)?.to_binary_file(output)?,
        (DocKind::Store, Codec::Json) => SketchStore::from_file(input)?.to_file(output)?,
        (DocKind::StoreSet, Codec::Binary) => {
            ShardedStore::from_file(input)?.to_binary_file(output)?
        }
        (DocKind::StoreSet, Codec::Json) => ShardedStore::from_file(input)?.to_file(output)?,
    }
    let bytes_out = std::fs::metadata(output)?.len();
    Ok(ConvertReport { doc, from, to, bytes_in: bytes.len() as u64, bytes_out })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::RadiusKind;
    use crate::testing::gen;
    use crate::util::rng::Rng;

    fn spec(seed: u64, m: usize, n: usize) -> OpSpec {
        OpSpec::derive(seed, RadiusKind::AdaptedRadius, 1.0, m, n).0
    }

    fn tempdir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("ckm_ckpt_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// A quantized multi-epoch store with a partially filled open epoch.
    fn quantized_store(seed: u64, epochs: usize) -> SketchStore {
        let mut store = SketchStore::create(
            spec(seed, 64, 3),
            Some(QuantizationMode::Bits(2)),
            5,
            Some(16),
        )
        .unwrap();
        let mut rng = Rng::new(seed ^ 0xABCD);
        for _ in 0..epochs {
            store.ingest(&gen::mat_normal(&mut rng, 17, 3));
            store.rotate();
        }
        store.ingest(&gen::mat_normal(&mut rng, 9, 3));
        store
    }

    fn assert_stores_identical(a: &SketchStore, b: &SketchStore) {
        assert_eq!(a.epoch_stats(), b.epoch_stats());
        assert_eq!(a.epoch_artifacts(), b.epoch_artifacts());
        assert_eq!(a.rows_ingested(), b.rows_ingested());
        assert_eq!(a.next_epoch_id(), b.next_epoch_id());
        assert_eq!(a.shard(), b.shard());
        assert_eq!(a.dither_seed(), b.dither_seed());
        assert_eq!(a.quantization(), b.quantization());
        assert_eq!(a.capacity(), b.capacity());
        assert_eq!(a.compaction(), b.compaction());
        assert_eq!(a.window_all(), b.window_all());
    }

    #[test]
    fn quantized_store_roundtrips_bit_identically() {
        let store = quantized_store(11, 4);
        let bytes = store_image(&store).to_bytes();
        let mut back = store_from_container(&bytes).unwrap();
        assert_stores_identical(&store, &back);

        // Resumed ingest stays bit-compatible with an uninterrupted run:
        // the dither row counter survives the binary codec too.
        let mut store = store;
        let mut rng = Rng::new(99);
        let extra = gen::mat_normal(&mut rng, 12, 3);
        store.ingest(&extra);
        back.ingest(&extra);
        assert_eq!(store.window_all(), back.window_all());
    }

    #[test]
    fn dense_store_roundtrips() {
        let mut store = SketchStore::create(spec(3, 32, 2), None, 0, None).unwrap();
        let mut rng = Rng::new(4);
        store.ingest(&gen::mat_normal(&mut rng, 10, 2));
        store.rotate();
        store.ingest(&gen::mat_normal(&mut rng, 6, 2));
        let bytes = store_image(&store).to_bytes();
        let back = store_from_container(&bytes).unwrap();
        assert_stores_identical(&store, &back);
    }

    #[test]
    fn binary_is_at_least_4x_smaller_than_json() {
        let store = quantized_store(21, 6);
        let json = store.to_json().to_pretty();
        let binary = store_image(&store).to_bytes();
        assert!(
            json.len() >= 4 * binary.len(),
            "json {} bytes vs binary {} bytes",
            json.len(),
            binary.len()
        );
    }

    #[test]
    fn store_set_roundtrips_bit_identically() {
        let set = ShardedStore::create(
            spec(7, 32, 2),
            Some(QuantizationMode::OneBit),
            3,
            2,
            Some(8),
            CompactionPolicy::None,
        )
        .unwrap();
        let mut rng = Rng::new(8);
        for _ in 0..3 {
            set.ingest(0, &gen::mat_normal(&mut rng, 7, 2));
            set.ingest(1, &gen::mat_normal(&mut rng, 5, 2));
            set.rotate_all();
        }
        let bytes = store_set_image(set.base_shard(), &set.snapshot()).to_bytes();
        let back = store_set_from_container(&bytes).unwrap();
        assert_eq!(back.n_shards(), 2);
        assert_eq!(back.base_shard(), 3);
        assert_eq!(back.quantization(), set.quantization());
        let (a, _) = set.merged_window(None).unwrap();
        let (b, _) = back.merged_window(None).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn append_keeps_sealed_epoch_bytes_untouched() {
        let dir = tempdir("append");
        let path = dir.join("wal.ckmc");
        let _ = std::fs::remove_file(&path);

        let mut store = SketchStore::create(
            spec(31, 48, 2),
            Some(QuantizationMode::Bits(2)),
            0,
            None,
        )
        .unwrap();
        let mut rng = Rng::new(32);
        store.ingest(&gen::mat_normal(&mut rng, 11, 2));
        store.rotate();
        store.ingest(&gen::mat_normal(&mut rng, 5, 2));

        // First call: file missing, full write.
        let s0 = append_store_to_file(&store, &path).unwrap();
        assert!(s0.rewritten);
        let b0 = std::fs::read(&path).unwrap();
        let cut = ContainerReader::parse(&b0).unwrap().append_offset() as usize;

        // Seal the open epoch and grow: the next checkpoint must append.
        store.rotate();
        store.ingest(&gen::mat_normal(&mut rng, 8, 2));
        let s1 = append_store_to_file(&store, &path).unwrap();
        assert!(!s1.rewritten);
        // meta + first sealed epoch kept; previously-open epoch changed
        // (it sealed), so it and the new open epoch were appended.
        assert!(s1.kept >= 2, "kept {}", s1.kept);
        assert!(s1.appended >= 1, "appended {}", s1.appended);

        let b1 = std::fs::read(&path).unwrap();
        assert!(b1.len() > b0.len());
        // Every byte up to the old footer start is untouched.
        assert_eq!(&b1[..cut], &b0[..cut]);

        let back = store_from_container(&b1).unwrap();
        assert_stores_identical(&store, &back);
    }

    #[test]
    fn append_heals_a_torn_tail_by_rewriting() {
        let dir = tempdir("torn");
        let path = dir.join("wal.ckmc");
        let store = quantized_store(41, 2);
        append_store_to_file(&store, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();

        let stats = append_store_to_file(&store, &path).unwrap();
        assert!(stats.rewritten);
        let back = store_from_container(&std::fs::read(&path).unwrap()).unwrap();
        assert_stores_identical(&store, &back);
    }

    #[test]
    fn append_refuses_a_foreign_stores_file() {
        let dir = tempdir("foreign");
        let path = dir.join("wal.ckmc");
        append_store_to_file(&quantized_store(51, 2), &path).unwrap();
        let other = quantized_store(52, 2);
        let err = append_store_to_file(&other, &path).unwrap_err();
        assert!(matches!(err, ApiError::Format(_)), "got {err}");
        // the original file is intact
        store_from_container(&std::fs::read(&path).unwrap()).unwrap();
    }

    /// A 2-shard quantized set with a few rotated epochs per shard.
    fn quantized_set(seed: u64, rounds: usize) -> ShardedStore {
        let set = ShardedStore::create(
            spec(seed, 32, 2),
            Some(QuantizationMode::OneBit),
            3,
            2,
            Some(16),
            CompactionPolicy::None,
        )
        .unwrap();
        let mut rng = Rng::new(seed ^ 0x5E7);
        for _ in 0..rounds {
            set.ingest(0, &gen::mat_normal(&mut rng, 7, 2));
            set.ingest(1, &gen::mat_normal(&mut rng, 5, 2));
            set.rotate_all();
        }
        set
    }

    fn assert_sets_identical(a: &ShardedStore, b: &ShardedStore) {
        assert_eq!(a.n_shards(), b.n_shards());
        assert_eq!(a.base_shard(), b.base_shard());
        assert_eq!(a.shard_stats(), b.shard_stats());
        let (wa, _) = a.merged_window(None).unwrap();
        let (wb, _) = b.merged_window(None).unwrap();
        assert_eq!(wa, wb);
    }

    #[test]
    fn set_wal_appends_without_touching_any_existing_byte() {
        let dir = tempdir("set_wal");
        let path = dir.join("set.wal.ckmc");
        let _ = std::fs::remove_file(&path);
        let set = quantized_set(81, 2);

        let s0 = append_store_set_to_file(&set, &path).unwrap();
        assert!(s0.rewritten);
        let b0 = std::fs::read(&path).unwrap();

        let mut rng = Rng::new(4242);
        set.ingest(0, &gen::mat_normal(&mut rng, 6, 2));
        set.rotate_all();
        let s1 = append_store_set_to_file(&set, &path).unwrap();
        assert!(!s1.rewritten);
        assert!(s1.kept >= 3, "kept {}", s1.kept); // meta + sealed epochs
        assert!(s1.appended >= 1, "appended {}", s1.appended);

        let b1 = std::fs::read(&path).unwrap();
        // The recoverable append's whole point: *every* byte of the
        // previous file — its footer and trailer included — is intact.
        assert_eq!(&b1[..b0.len()], &b0[..]);

        let (back, healed) = load_store_set_wal(&path).unwrap();
        assert!(!healed);
        assert_sets_identical(&set, &back);
    }

    #[test]
    fn set_wal_torn_tail_heals_to_the_previous_append() {
        let dir = tempdir("set_wal_torn");
        let path = dir.join("set.wal.ckmc");
        let _ = std::fs::remove_file(&path);
        let set = quantized_set(91, 2);
        append_store_set_to_file(&set, &path).unwrap();
        let snapshot_rows: usize =
            set.shard_stats().iter().map(|s| s.rows_ingested).sum();
        let b0 = std::fs::read(&path).unwrap();

        let mut rng = Rng::new(7);
        set.ingest(1, &gen::mat_normal(&mut rng, 9, 2));
        append_store_set_to_file(&set, &path).unwrap();
        let b1 = std::fs::read(&path).unwrap();

        // kill -9 mid-append: cut anywhere inside the appended tail.
        for cut in [b0.len() + 1, b1.len() - TRAILER_SPOT, b1.len() - 1] {
            std::fs::write(&path, &b1[..cut]).unwrap();
            let (back, healed) = load_store_set_wal(&path).unwrap();
            assert!(healed, "cut {cut}");
            let rows: usize = back.shard_stats().iter().map(|s| s.rows_ingested).sum();
            assert_eq!(rows, snapshot_rows, "cut {cut}");
            // healing truncated the file back to the valid prefix
            assert_eq!(std::fs::read(&path).unwrap(), b0, "cut {cut}");
        }

        // ...and the next append proceeds on the healed file.
        std::fs::write(&path, &b1[..b1.len() - 3]).unwrap();
        let stats = append_store_set_to_file(&set, &path).unwrap();
        assert!(!stats.rewritten);
        let (back, _) = load_store_set_wal(&path).unwrap();
        assert_sets_identical(&set, &back);
    }

    const TRAILER_SPOT: usize = 9; // a cut landing inside the new trailer

    #[test]
    fn set_wal_refuses_a_foreign_file() {
        let dir = tempdir("set_wal_foreign");
        let path = dir.join("set.wal.ckmc");
        let _ = std::fs::remove_file(&path);
        append_store_set_to_file(&quantized_set(101, 1), &path).unwrap();
        let other = quantized_set(102, 1);
        let err = append_store_set_to_file(&other, &path).unwrap_err();
        assert!(matches!(err, ApiError::Format(_)), "got {err}");
        load_store_set_wal(&path).unwrap();
    }

    #[test]
    fn detect_classifies_every_doc_and_codec() {
        let store = quantized_store(61, 2);
        let art = store.window_all();
        let set = ShardedStore::create(spec(7, 8, 2), None, 0, 1, None, CompactionPolicy::None)
            .unwrap();
        set.ingest(0, &gen::mat_normal(&mut Rng::new(1), 3, 2));

        let cases: Vec<(Vec<u8>, DocKind, Codec)> = vec![
            (art.to_json().to_pretty().into_bytes(), DocKind::Artifact, Codec::Json),
            (
                crate::api::artifact::binary::artifact_image(&art).to_bytes(),
                DocKind::Artifact,
                Codec::Binary,
            ),
            (store.to_json().to_pretty().into_bytes(), DocKind::Store, Codec::Json),
            (store_image(&store).to_bytes(), DocKind::Store, Codec::Binary),
            (set.to_json().to_pretty().into_bytes(), DocKind::StoreSet, Codec::Json),
            (
                store_set_image(set.base_shard(), &set.snapshot()).to_bytes(),
                DocKind::StoreSet,
                Codec::Binary,
            ),
        ];
        for (bytes, doc, codec) in cases {
            assert_eq!(detect(&bytes).unwrap(), (doc, codec), "{doc:?}/{codec:?}");
        }
        assert!(detect(b"not a checkpoint").is_err());
    }

    #[test]
    fn convert_roundtrips_through_both_codecs() {
        let dir = tempdir("convert");
        let json_path = dir.join("store.json");
        let ckmc_path = dir.join("store.ckmc");
        let json2_path = dir.join("store2.json");

        let store = quantized_store(71, 3);
        store.to_file(&json_path).unwrap();

        let r1 = convert_file(&json_path, &ckmc_path).unwrap();
        assert_eq!((r1.doc, r1.from, r1.to), (DocKind::Store, Codec::Json, Codec::Binary));
        assert!(r1.bytes_in >= 4 * r1.bytes_out, "{} vs {}", r1.bytes_in, r1.bytes_out);

        let r2 = convert_file(&ckmc_path, &json2_path).unwrap();
        assert_eq!((r2.from, r2.to), (Codec::Binary, Codec::Json));

        let a = SketchStore::from_file(&json_path).unwrap();
        let b = SketchStore::from_file(&ckmc_path).unwrap();
        let c = SketchStore::from_file(&json2_path).unwrap();
        assert_stores_identical(&a, &b);
        assert_stores_identical(&a, &c);
    }
}
