//! The concurrent serving layer over a [`SketchStore`]: many producer
//! threads, snapshot-solve consumers, and a generation-keyed solve cache.
//!
//! Producers obtain a per-thread [`IngestSession`] whose local
//! [`Batcher`] coalesces arbitrary-sized pushes into full chunks. Each
//! chunk then runs **two-phase ingest**: a short lock reserves the global
//! row-index range (the quantized dither keys), the full sketch math
//! (`X·Wᵀ` tile + trig sweep — the expensive part) runs *outside* the
//! store mutex on the producer's thread, and a second short lock merges
//! the finished chunk exactly. The critical section is two counter bumps
//! plus one `m`-length merge per chunk, so producers scale instead of
//! serializing on the sketch math. Solves snapshot the requested
//! window/decay artifact under the lock (cheap: a merge over ≤
//! ring-capacity epochs) and run the decoder *outside* it, so a long
//! decode never stalls ingest. Repeated queries against an unchanged
//! store are answered from a small solve cache keyed by `(query, K,
//! decoder, store generation)` — any ingest or rotation bumps the
//! generation and implicitly invalidates every cached solution, and a
//! solution decoded by one algorithm is never served for a request that
//! named another.
//!
//! Concurrency semantics: rows belong to whichever epoch is current when
//! their chunk's *merge* reaches the store, and the sketch value is
//! independent of producer interleaving up to floating-point addition
//! order (dense) / dither assignment (quantized: rows are dithered by
//! reservation order, so multi-producer ingest is statistically identical
//! to single-producer ingest but only single-producer arrival orders
//! replay bit-for-bit — those are bit-identical to the synchronous store
//! path, pinned by test).

use super::ring::{SketchContext, SketchStore};
use crate::api::{ApiError, Ckm, SketchArtifact};
use crate::ckm::Solution;
use crate::coordinator::batcher::Batcher;
use crate::decoder::DecoderSpec;
use std::sync::Mutex;

/// How many `(query, K, decoder)` solutions the server keeps per store
/// generation.
const SOLVE_CACHE_CAP: usize = 16;

/// A solve-cache key: the query shape, `K`, and the decoder that produced
/// the cached solution — two decoders legitimately return different
/// centroids for the same snapshot, so they must never share an entry.
#[derive(Clone, Debug, PartialEq)]
enum SolveKey {
    Window { last_e: usize, k: usize, decoder: DecoderSpec },
    /// λ keyed by bit pattern (exact: the caller's f64 is the key).
    Decayed { lambda_bits: u64, k: usize, decoder: DecoderSpec },
}

#[derive(Debug, Default)]
struct SolveCache {
    /// Store generation the entries were solved against.
    generation: u64,
    entries: Vec<(SolveKey, Solution)>,
    hits: u64,
    misses: u64,
}

impl SolveCache {
    /// Look up `key` against `generation`. The cache tracks the *newest*
    /// generation it has seen: a newer snapshot clears the stale entries,
    /// while a lagging solve (snapshot taken, then the store moved on
    /// before the lookup) is a plain miss — it must not wipe fresh entries
    /// or re-seat the cache at a generation the store will never revisit.
    fn get(&mut self, generation: u64, key: &SolveKey) -> Option<Solution> {
        if generation > self.generation {
            self.entries.clear();
            self.generation = generation;
        } else if generation < self.generation {
            self.misses += 1;
            return None;
        }
        match self.entries.iter().find(|(k, _)| k == key) {
            Some((_, sol)) => {
                self.hits += 1;
                Some(sol.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert a solution solved against `generation` (dropped if the store
    /// moved on while the solve ran — a stale answer must not be cached).
    fn put(&mut self, generation: u64, key: SolveKey, sol: &Solution) {
        if self.generation != generation {
            return;
        }
        if self.entries.len() >= SOLVE_CACHE_CAP {
            self.entries.remove(0);
        }
        self.entries.push((key, sol.clone()));
    }
}

/// Aggregate server counters (see [`SketchServer::stats`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ServerStats {
    /// Surviving epochs in the ring.
    pub epochs: usize,
    /// Rows across surviving epochs.
    pub surviving_rows: usize,
    /// Store-lifetime rows (includes evicted epochs).
    pub rows_ingested: usize,
    /// Store mutation counter.
    pub generation: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// A concurrent windowed-sketch service: shared-reference ingest from any
/// number of producer threads, cached snapshot solves for any consumer.
///
/// Construct via [`crate::api::Ckm::server`]; the facade's `.window(..)` /
/// `.decay(..)` knobs set the ring capacity and the default decay used by
/// [`SketchServer::solve`].
#[derive(Debug)]
pub struct SketchServer {
    store: Mutex<SketchStore>,
    /// Immutable sketch context (operator, quantization, dither seed):
    /// lets every producer run the sketch math without touching the lock.
    ctx: SketchContext,
    solver: Ckm,
    cache: Mutex<SolveCache>,
    chunk_rows: usize,
}

impl SketchServer {
    /// Wrap a store with a solving facade. `solver`'s sketcher chunk size
    /// becomes the per-session batching granularity.
    pub fn new(store: SketchStore, solver: Ckm) -> SketchServer {
        let chunk_rows = solver.config().sketcher.chunk_rows.max(1);
        let ctx = store.sketch_context();
        SketchServer {
            store: Mutex::new(store),
            ctx,
            solver,
            cache: Mutex::new(SolveCache::default()),
            chunk_rows,
        }
    }

    /// The solving facade this server answers queries with.
    pub fn solver(&self) -> &Ckm {
        &self.solver
    }

    // -- ingest side ------------------------------------------------------

    /// Open a per-producer ingest session (local chunking; call
    /// [`IngestSession::finish`] to flush the tail).
    pub fn session(&self) -> IngestSession<'_> {
        IngestSession { server: self, batcher: Batcher::new(self.ctx.n_dims(), self.chunk_rows) }
    }

    /// Ingest rows through the two-phase path: reserve the global row
    /// range under a short lock, run the sketch math (the expensive
    /// `X·Wᵀ` + trig sweep) with *no* lock held, then merge the finished
    /// chunk under a second short lock. Prefer [`SketchServer::session`]
    /// for high-frequency small pushes. Returns rows absorbed.
    pub fn ingest(&self, rows: &[f64]) -> usize {
        let n = self.ctx.n_dims();
        assert_eq!(rows.len() % n, 0, "non-integral row ingest");
        let n_rows = rows.len() / n;
        if n_rows == 0 {
            return 0;
        }
        // Phase 1 — short lock: reserve the dither row-key range.
        let offset = self.store.lock().unwrap().reserve_rows(n_rows);
        // Phase 2 — no lock: the sketch math runs on this producer's thread.
        let chunk = self.ctx.sketch_chunk(rows, offset);
        // Phase 3 — short lock: exact merge into the current epoch.
        self.store.lock().unwrap().absorb(chunk)
    }

    /// Seal the current epoch and open the next (see
    /// [`SketchStore::rotate`]). Returns the evicted epoch ids.
    pub fn rotate(&self) -> Vec<u64> {
        self.store.lock().unwrap().rotate()
    }

    // -- query side -------------------------------------------------------

    /// Snapshot the newest `last_e` epochs as one artifact.
    pub fn window(&self, last_e: usize) -> Result<SketchArtifact, ApiError> {
        self.store.lock().unwrap().window(last_e)
    }

    /// Snapshot every surviving epoch.
    pub fn window_all(&self) -> SketchArtifact {
        self.store.lock().unwrap().window_all()
    }

    /// Snapshot the exponentially-decayed sketch.
    pub fn decayed(&self, lambda: f64) -> Result<SketchArtifact, ApiError> {
        self.store.lock().unwrap().decayed(lambda)
    }

    /// Checkpoint the whole store to one file.
    pub fn save<P: AsRef<std::path::Path>>(&self, path: P) -> Result<(), ApiError> {
        self.store.lock().unwrap().to_file(path)
    }

    /// Replace the live store with a checkpoint (same provenance required:
    /// operator spec, quantization, shard). The restored store's
    /// generation is forced strictly past the replaced store's and the
    /// solve cache is cleared and re-seated, so a cached solve computed
    /// against pre-restore state can never be served afterwards — the
    /// first query after a restore always re-solves.
    pub fn restore<P: AsRef<std::path::Path>>(&self, path: P) -> Result<(), ApiError> {
        let mut fresh = SketchStore::from_file(path)?;
        let mut store = self.store.lock().unwrap();
        if fresh.spec() != store.spec() {
            return Err(ApiError::OperatorMismatch {
                left: store.spec().describe(),
                right: fresh.spec().describe(),
            });
        }
        if fresh.quantization() != store.quantization() || fresh.shard() != store.shard() {
            return Err(ApiError::QuantizationMismatch {
                left: format!(
                    "store(quant {:?}, shard {})",
                    store.quantization(),
                    store.shard()
                ),
                right: format!(
                    "checkpoint(quant {:?}, shard {})",
                    fresh.quantization(),
                    fresh.shard()
                ),
            });
        }
        fresh.bump_generation_past(store.generation());
        *store = fresh;
        // Lock order store → cache (the only place both are held): clear
        // stale entries and re-seat the cache at the restored generation,
        // so an in-flight `put` against the old generation is dropped.
        let mut cache = self.cache.lock().unwrap();
        cache.entries.clear();
        cache.generation = store.generation();
        Ok(())
    }

    /// Solve `k` centroids over the newest `last_e` epochs (cached) with
    /// the facade's configured decoder.
    pub fn solve_window(&self, last_e: usize, k: usize) -> Result<Solution, ApiError> {
        self.solve_window_with(last_e, k, self.solver.config().decoder)
    }

    /// Solve `k` centroids over the newest `last_e` epochs with an explicit
    /// decoder (cached; the decoder is part of the cache key).
    pub fn solve_window_with(
        &self,
        last_e: usize,
        k: usize,
        decoder: DecoderSpec,
    ) -> Result<Solution, ApiError> {
        let (generation, artifact) = {
            let store = self.store.lock().unwrap();
            (store.generation(), store.window(last_e)?)
        };
        self.solve_cached(generation, SolveKey::Window { last_e, k, decoder }, &artifact, k, decoder)
    }

    /// Solve `k` centroids over the λ-decayed sketch (cached) with the
    /// facade's configured decoder.
    pub fn solve_decayed(&self, lambda: f64, k: usize) -> Result<Solution, ApiError> {
        self.solve_decayed_with(lambda, k, self.solver.config().decoder)
    }

    /// Solve `k` centroids over the λ-decayed sketch with an explicit
    /// decoder (cached; the decoder is part of the cache key).
    pub fn solve_decayed_with(
        &self,
        lambda: f64,
        k: usize,
        decoder: DecoderSpec,
    ) -> Result<Solution, ApiError> {
        let (generation, artifact) = {
            let store = self.store.lock().unwrap();
            (store.generation(), store.decayed(lambda)?)
        };
        let key = SolveKey::Decayed { lambda_bits: lambda.to_bits(), k, decoder };
        self.solve_cached(generation, key, &artifact, k, decoder)
    }

    /// Solve with the facade's defaults: the builder's `.decay(λ)` when
    /// set, otherwise the plain merge of every surviving epoch.
    pub fn solve(&self, k: usize) -> Result<Solution, ApiError> {
        match self.solver.config().decay {
            Some(lambda) => self.solve_decayed(lambda, k),
            None => self.solve_window(usize::MAX, k),
        }
    }

    fn solve_cached(
        &self,
        generation: u64,
        key: SolveKey,
        artifact: &SketchArtifact,
        k: usize,
        decoder: DecoderSpec,
    ) -> Result<Solution, ApiError> {
        if let Some(sol) = self.cache.lock().unwrap().get(generation, &key) {
            return Ok(sol);
        }
        // The decoder runs outside both locks: ingest keeps flowing.
        let sol = self.solver.solve_with_decoder(artifact, k, decoder)?;
        self.cache.lock().unwrap().put(generation, key, &sol);
        Ok(sol)
    }

    /// Aggregate counters (store + cache).
    pub fn stats(&self) -> ServerStats {
        let store = self.store.lock().unwrap();
        let cache = self.cache.lock().unwrap();
        ServerStats {
            epochs: store.epoch_count(),
            surviving_rows: store.surviving_rows(),
            rows_ingested: store.rows_ingested(),
            generation: store.generation(),
            cache_hits: cache.hits,
            cache_misses: cache.misses,
        }
    }

    /// Run `f` against the locked store (introspection escape hatch).
    pub fn with_store<T>(&self, f: impl FnOnce(&SketchStore) -> T) -> T {
        f(&self.store.lock().unwrap())
    }
}

/// A per-producer ingest handle: pushes of any size are coalesced into
/// full chunks by a local [`Batcher`], and each full chunk takes the store
/// lock exactly once. Call [`IngestSession::finish`] to flush the partial
/// tail — rows left in an unfinished session are dropped.
pub struct IngestSession<'a> {
    server: &'a SketchServer,
    batcher: Batcher,
}

impl<'a> IngestSession<'a> {
    /// Buffer rows, forwarding every completed chunk to the store.
    pub fn push(&mut self, rows: &[f64]) {
        for chunk in self.batcher.push(rows) {
            self.server.ingest(&chunk);
        }
    }

    /// Rows this session has already forwarded to the store.
    pub fn forwarded_rows(&self) -> usize {
        self.batcher.emitted_rows()
    }

    /// Flush the partial tail and return the total rows this session
    /// forwarded.
    pub fn finish(mut self) -> usize {
        if let Some(tail) = self.batcher.flush() {
            self.server.ingest(&tail);
        }
        self.batcher.emitted_rows()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::OpSpec;
    use crate::sketch::RadiusKind;
    use crate::testing::gen;
    use crate::util::rng::Rng;

    fn server(m: usize, n: usize) -> SketchServer {
        let spec = OpSpec::derive(21, RadiusKind::AdaptedRadius, 1.0, m, n).0;
        let store = SketchStore::create(spec, None, 0, None).unwrap();
        let solver =
            Ckm::builder().frequencies(m).sigma2(1.0).seed(21).chunk_rows(8).build().unwrap();
        SketchServer::new(store, solver)
    }

    #[test]
    fn sessions_chunk_and_flush() {
        let srv = server(16, 3);
        let mut rng = Rng::new(1);
        let pts = gen::mat_normal(&mut rng, 21, 3);
        let mut sess = srv.session();
        sess.push(&pts[..5 * 3]);
        sess.push(&pts[5 * 3..]);
        assert_eq!(sess.forwarded_rows(), 16); // two full 8-row chunks
        assert_eq!(sess.finish(), 21);
        assert_eq!(srv.stats().rows_ingested, 21);
        assert_eq!(srv.window_all().count, 21);
    }

    #[test]
    fn solve_cache_hits_until_generation_moves() {
        let srv = server(32, 2);
        let mut rng = Rng::new(2);
        srv.ingest(&gen::mat_normal(&mut rng, 300, 2));
        let a = srv.solve_window(1, 2).unwrap();
        let b = srv.solve_window(1, 2).unwrap();
        assert_eq!(a.centroids.data, b.centroids.data);
        assert_eq!(a.alpha, b.alpha);
        let s = srv.stats();
        assert_eq!(s.cache_hits, 1);
        assert_eq!(s.cache_misses, 1);
        // a different K is a different key
        srv.solve_window(1, 3).unwrap();
        assert_eq!(srv.stats().cache_misses, 2);
        // any mutation invalidates
        srv.rotate();
        srv.solve_window(1, 2).unwrap_err(); // newest epoch now empty
        srv.ingest(&gen::mat_normal(&mut rng, 50, 2));
        srv.solve_window(2, 2).unwrap();
        let s = srv.stats();
        assert_eq!(s.cache_hits, 1);
        assert!(s.cache_misses >= 3);
    }

    #[test]
    fn solve_cache_never_crosses_decoders() {
        // A cached CLOMPR answer must not be served for a sketch-shift
        // request against the same (query, K, generation) — the decoder is
        // part of the key, not a post-hoc label.
        let srv = server(64, 2);
        let mut rng = Rng::new(9);
        srv.ingest(&gen::mat_normal(&mut rng, 400, 2));
        let clompr = srv.solve_window(1, 2).unwrap();
        assert_eq!(clompr.decoder, DecoderSpec::Clompr);
        assert_eq!(srv.stats().cache_misses, 1);
        // same query + K, different decoder: must MISS and re-solve
        let shift = srv.solve_window_with(1, 2, DecoderSpec::SketchShift).unwrap();
        assert_eq!(shift.decoder, DecoderSpec::SketchShift);
        let s = srv.stats();
        assert_eq!(s.cache_hits, 0);
        assert_eq!(s.cache_misses, 2);
        // each decoder now hits its own entry
        let clompr2 = srv.solve_window(1, 2).unwrap();
        let shift2 = srv.solve_window_with(1, 2, DecoderSpec::SketchShift).unwrap();
        assert_eq!(srv.stats().cache_hits, 2);
        assert_eq!(clompr2.centroids.data, clompr.centroids.data);
        assert_eq!(shift2.centroids.data, shift.centroids.data);
        // decayed queries key on the decoder too
        let d1 = srv.solve_decayed_with(0.5, 2, DecoderSpec::Clompr).unwrap();
        let d2 = srv.solve_decayed_with(0.5, 2, DecoderSpec::Hierarchical).unwrap();
        assert_eq!(d1.decoder, DecoderSpec::Clompr);
        assert_eq!(d2.decoder, DecoderSpec::Hierarchical);
        assert_eq!(srv.stats().cache_misses, 4);
    }

    #[test]
    fn two_phase_session_matches_facade_sketch_bit_for_bit() {
        // Quantized server: chunks sketch OUTSIDE the lock with reserved
        // dither keys. A single producer's result must equal the facade's
        // single-pass quantized sketch bit for bit — this pins the
        // reserve → sketch → absorb flow (keying dithers at merge time
        // instead of reservation time would fail it).
        let ckm = Ckm::builder()
            .frequencies(32)
            .sigma2(1.0)
            .seed(31)
            .chunk_rows(16)
            .quantization(crate::sketch::QuantizationMode::OneBit)
            .build()
            .unwrap();
        let srv = ckm.server(3).unwrap();
        let mut rng = Rng::new(32);
        let pts = gen::mat_normal(&mut rng, 103, 3); // ragged vs chunk_rows
        let mut sess = srv.session();
        sess.push(&pts);
        assert_eq!(sess.finish(), 103);
        let win = srv.window_all();
        let direct = ckm.sketch_slice(&pts, 3).unwrap();
        assert_eq!(win, direct);
    }

    #[test]
    fn restore_never_serves_a_pre_checkpoint_cached_solve() {
        // solve (cached) → checkpoint → ingest more + solve (cache holds
        // the newer answer) → restore the checkpoint → the next solve must
        // re-solve against the restored state, not serve either cached
        // generation.
        let srv = server(32, 2);
        let mut rng = Rng::new(7);
        srv.ingest(&gen::mat_normal(&mut rng, 300, 2));
        let at_checkpoint = srv.solve_window(1, 2).unwrap();
        let path =
            std::env::temp_dir().join(format!("ckm_restore_{}.json", std::process::id()));
        srv.save(&path).unwrap();

        srv.ingest(&gen::mat_normal(&mut rng, 300, 2));
        let later = srv.solve_window(1, 2).unwrap();
        assert_ne!(later.centroids.data, at_checkpoint.centroids.data);
        let before = srv.stats();

        srv.restore(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let after_restore = srv.stats();
        assert!(
            after_restore.generation > before.generation,
            "restored generation {} must move past live generation {}",
            after_restore.generation,
            before.generation
        );
        assert_eq!(after_restore.rows_ingested, 300);

        let resolved = srv.solve_window(1, 2).unwrap();
        // fresh solve, not a cache hit...
        assert_eq!(srv.stats().cache_hits, before.cache_hits);
        assert_eq!(srv.stats().cache_misses, before.cache_misses + 1);
        // ...and it answers for the checkpointed rows, bit for bit
        assert_eq!(resolved.centroids.data, at_checkpoint.centroids.data);
        assert_eq!(resolved.alpha, at_checkpoint.alpha);
    }

    #[test]
    fn restore_rejects_mismatched_provenance() {
        let srv = server(32, 2);
        let path =
            std::env::temp_dir().join(format!("ckm_restore_bad_{}.json", std::process::id()));
        // a store from a different operator seed
        let other_spec = OpSpec::derive(99, RadiusKind::AdaptedRadius, 1.0, 32, 2).0;
        let other = SketchStore::create(other_spec, None, 0, None).unwrap();
        other.to_file(&path).unwrap();
        assert!(matches!(srv.restore(&path), Err(ApiError::OperatorMismatch { .. })));
        // same operator, different shard salt
        let same_spec = OpSpec::derive(21, RadiusKind::AdaptedRadius, 1.0, 32, 2).0;
        let shifted = SketchStore::create(same_spec, None, 5, None).unwrap();
        shifted.to_file(&path).unwrap();
        assert!(matches!(srv.restore(&path), Err(ApiError::QuantizationMismatch { .. })));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn default_solve_uses_builder_decay() {
        let spec = OpSpec::derive(22, RadiusKind::AdaptedRadius, 1.0, 32, 2).0;
        let store = SketchStore::create(spec, None, 0, None).unwrap();
        let solver =
            Ckm::builder().frequencies(32).sigma2(1.0).seed(22).decay(0.5).build().unwrap();
        let srv = SketchServer::new(store, solver);
        let mut rng = Rng::new(3);
        srv.ingest(&gen::mat_normal(&mut rng, 200, 2));
        srv.rotate();
        srv.ingest(&gen::mat_normal(&mut rng, 200, 2));
        let by_default = srv.solve(2).unwrap();
        let by_lambda = srv.solve_decayed(0.5, 2).unwrap();
        assert_eq!(by_default.centroids.data, by_lambda.centroids.data);
        assert_eq!(srv.stats().cache_hits, 1); // same key, same generation
    }
}
