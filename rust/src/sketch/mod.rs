//! Sketching layer: frequency sampling, the operator `A`, batched atom
//! kernels, σ² estimation, the mergeable streaming accumulator (paper
//! §3.1 and §3.3 steps 1–3) and the dithered quantization layer (QCKM).

pub mod frequencies;
pub mod kernels;
pub mod operator;
pub mod quantize;
pub mod scale;
pub mod streaming;

pub use frequencies::{FreqDist, RadiusKind};
pub use operator::SketchOp;
pub use quantize::{QuantizationMode, QuantizedAccumulator};
pub use streaming::{sketch_source, SketchAccumulator};

use crate::data::dataset::Bounds;
use crate::linalg::CVec;
use crate::util::rng::Rng;

/// Everything CLOMPR needs: the sketch, the operator, bounds and count.
pub struct DatasetSketch {
    pub z: CVec,
    pub op: SketchOp,
    pub bounds: Bounds,
    pub count: usize,
    /// The σ² the frequencies were drawn with (for reporting).
    pub sigma2: f64,
}

/// One-call pipeline: estimate σ² on (a fraction of) the data, draw `m`
/// frequencies, sketch the whole dataset. `sigma2` overrides estimation.
pub fn sketch_dataset(
    points: &[f64],
    n_dims: usize,
    m: usize,
    seed: u64,
    sigma2: Option<f64>,
) -> DatasetSketch {
    let mut rng = Rng::new(seed);
    let sigma2 = sigma2.unwrap_or_else(|| {
        scale::ScaleEstimator::default().estimate(points, n_dims, &mut rng)
    });
    let dist = FreqDist::adapted(sigma2);
    let op = SketchOp::new(dist.draw(m, n_dims, &mut rng));
    let mut acc = SketchAccumulator::new(m, n_dims);
    acc.update(&op, points);
    DatasetSketch { z: acc.finalize(), bounds: acc.bounds.clone(), count: acc.count, op, sigma2 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gmm::GmmConfig;

    #[test]
    fn one_call_pipeline() {
        let mut rng = Rng::new(0);
        let g = GmmConfig::paper_default(3, 5, 3000).generate(&mut rng);
        let sk = sketch_dataset(&g.dataset.points, 5, 128, 7, None);
        assert_eq!(sk.z.len(), 128);
        assert_eq!(sk.count, 3000);
        assert!(sk.bounds.is_valid());
        assert!(sk.sigma2 > 0.0);
        // sketch of a real dataset has |z_0..| ≤ 1 and nonzero energy
        assert!(sk.z.norm2() > 0.0);
        assert!(sk.z.modulus().iter().all(|&v| v <= 1.0 + 1e-9));
    }

    #[test]
    fn sigma2_override_respected() {
        let mut rng = Rng::new(1);
        let g = GmmConfig::paper_default(2, 3, 500).generate(&mut rng);
        let sk = sketch_dataset(&g.dataset.points, 3, 32, 9, Some(2.5));
        assert_eq!(sk.sigma2, 2.5);
    }
}
