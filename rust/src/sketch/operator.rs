//! The sketching operator `A` and its native (pure-rust) evaluation.
//!
//! `A p = [E_{x~p} e^{-i ω_j^T x}]_{j=1..m}` — sampling the characteristic
//! function at the drawn frequencies. For point sets this is
//! `Sk(Y, β)_j = Σ_l β_l e^{-i ω_j^T y_l}` (paper eq. 3).
//!
//! This module is the *native engine*: the correctness oracle for the
//! PJRT/AOT path and the fallback for shapes outside the compiled matrix.
//! The hot loop (`X·Wᵀ` then cos/sin accumulation) is blocked and
//! multi-threaded; the same math is what the Pallas kernel implements.
//!
//! The per-atom methods here (`atom`, `mixture_sketch`,
//! `step5_value_grads`) are the scalar oracles for the batched GEMM
//! kernels in [`super::kernels`], which the solvers use in production;
//! property tests pin the two bit-for-bit.
//!
//! Gradient identities used by CLOMPR (derivation in DESIGN.md §2):
//! with θ_j = ω_j^T c and r the residual,
//!   Re⟨Aδ_c, r⟩ = Σ_j cosθ_j·Re r_j − sinθ_j·Im r_j
//!   ∇_c Re⟨Aδ_c, r⟩ = Wᵀ q,  q_j = −(sinθ_j·Re r_j + cosθ_j·Im r_j)
//! and ‖Aδ_c‖ = √m exactly (unit-modulus entries).

use crate::linalg::matrix::matmul_bt_block;
use crate::linalg::{CVec, Mat};
use crate::util::fastmath::{self, TrigBackend};
use crate::util::parallel;
use std::sync::OnceLock;

/// The sketching operator: a frequency matrix `W (m × n)` plus the trig
/// backend its ECF sweeps run on.
#[derive(Clone, Debug)]
pub struct SketchOp {
    pub w: Mat,
    /// Cached `Wᵀ` for the batched `Q·W` gradient GEMM (computed on first
    /// use; `W` is immutable for the life of the operator).
    wt: OnceLock<Mat>,
    /// Which sin/cos implementation every sweep of this operator uses.
    /// Part of the artifact provenance: `Exact` is bit-identical to the
    /// historical libm paths, `Fast` is the vectorized kernel
    /// ([`crate::util::fastmath`], ≤ 2 ULP).
    trig: TrigBackend,
}

impl SketchOp {
    pub fn new(w: Mat) -> SketchOp {
        SketchOp::with_trig(w, TrigBackend::Exact)
    }

    /// Operator with an explicit trig backend (see [`TrigBackend`]).
    pub fn with_trig(w: Mat, trig: TrigBackend) -> SketchOp {
        SketchOp { w, wt: OnceLock::new(), trig }
    }

    /// The trig backend every sweep of this operator dispatches on.
    pub fn trig(&self) -> TrigBackend {
        self.trig
    }

    /// `(sin θ, cos θ)` under this operator's backend (scalar sites; the
    /// sweeps below and in [`super::kernels`] handle the hot loops).
    #[inline]
    pub(crate) fn sincos(&self, t: f64) -> (f64, f64) {
        fastmath::sincos(self.trig, t)
    }

    /// `Wᵀ (n × m)`, transposed once and cached.
    pub fn w_t(&self) -> &Mat {
        self.wt.get_or_init(|| self.w.transpose())
    }

    pub fn m(&self) -> usize {
        self.w.rows
    }

    pub fn n_dims(&self) -> usize {
        self.w.cols
    }

    /// `A δ_c` — the atom at centroid `c`.
    pub fn atom(&self, c: &[f64]) -> CVec {
        let theta = self.w.matvec(c);
        let mut a = CVec::zeros(self.m());
        fastmath::atom_sweep(self.trig, &theta, &mut a.re, &mut a.im);
        a
    }

    /// `‖A δ_c‖₂` — constant √m for the Fourier sketch.
    pub fn atom_norm(&self) -> f64 {
        (self.m() as f64).sqrt()
    }

    /// Value and gradient of `f(c) = Re⟨A δ_c / ‖A δ_c‖, r⟩`.
    pub fn step1_value_grad(&self, c: &[f64], r: &CVec) -> (f64, Vec<f64>) {
        let inv_norm = 1.0 / self.atom_norm();
        let theta = self.w.matvec(c);
        let m = self.m();
        let mut val = 0.0;
        let mut q = vec![0.0; m];
        for j in 0..m {
            let (s, co) = self.sincos(theta[j]);
            val += co * r.re[j] - s * r.im[j];
            q[j] = -(s * r.re[j] + co * r.im[j]);
        }
        let mut grad = self.w.matvec_t(&q);
        for g in grad.iter_mut() {
            *g *= inv_norm;
        }
        (val * inv_norm, grad)
    }

    /// Sketch of a weighted mixture of Diracs: `Σ_k α_k A δ_{c_k}`.
    /// `centroids` is row-major `k × n`.
    pub fn mixture_sketch(&self, centroids: &Mat, alpha: &[f64]) -> CVec {
        assert_eq!(centroids.rows, alpha.len());
        let mut z = CVec::zeros(self.m());
        for (k, &a) in alpha.iter().enumerate() {
            if a == 0.0 {
                continue;
            }
            let atom = self.atom(centroids.row(k));
            z.axpy(a, &atom);
        }
        z
    }

    /// Cost `g(C, α) = ‖ẑ − Σ_k α_k A δ_{c_k}‖²` and its gradients
    /// `(∂g/∂C (k×n), ∂g/∂α (k))`. Returns `(cost, grad_c, grad_alpha)`.
    pub fn step5_value_grads(
        &self,
        z_hat: &CVec,
        centroids: &Mat,
        alpha: &[f64],
    ) -> (f64, Mat, Vec<f64>) {
        let kk = centroids.rows;
        let m = self.m();
        // Atoms and residual r = ẑ − Σ α_k u_k.
        let mut atoms: Vec<CVec> = Vec::with_capacity(kk);
        let mut r = z_hat.clone();
        for k in 0..kk {
            let u = self.atom(centroids.row(k));
            r.axpy(-alpha[k], &u);
            atoms.push(u);
        }
        let cost = r.norm2_sq();
        let mut grad_c = Mat::zeros(kk, self.n_dims());
        let mut grad_a = vec![0.0; kk];
        let mut q = vec![0.0; m];
        for k in 0..kk {
            let u = &atoms[k];
            // ∂g/∂α_k = −2 Re⟨u_k, r⟩
            grad_a[k] = -2.0 * u.re_dot(&r);
            // ∇_{c_k} g = −2 α_k Wᵀ q with q_j = −(sinθ·Re r + cosθ·Im r);
            // note u.re = cosθ, u.im = −sinθ.
            for j in 0..m {
                let (co, s) = (u.re[j], -u.im[j]);
                q[j] = -(s * r.re[j] + co * r.im[j]);
            }
            let g = self.w.matvec_t(&q);
            let row = grad_c.row_mut(k);
            for (d, gv) in g.iter().enumerate() {
                row[d] = -2.0 * alpha[k] * gv;
            }
        }
        (cost, grad_c, grad_a)
    }

    /// Sketch a weighted point set: `Σ_l β_l e^{-i ω_j^T x_l}` with β
    /// uniform `1/N` when `weights` is `None`. Multi-threaded, blocked.
    pub fn sketch_points(&self, points: &[f64], weights: Option<&[f64]>) -> CVec {
        let mut z = self.sketch_points_sum(points, weights);
        if weights.is_none() {
            // Uniform weights: the sweep accumulated raw sums; one scale
            // at the end replaces N·m per-element β multiplies.
            let n_points = points.len() / self.n_dims().max(1);
            if n_points > 0 {
                z.scale(1.0 / n_points as f64);
            }
        }
        z
    }

    /// The *unnormalized* sketch sum `Σ_l β_l e^{-i ω_j^T x_l}` with β ≡ 1
    /// when `weights` is `None` — the raw accumulator quantum streaming
    /// ingest merges (no per-element normalization, no rescaling churn).
    ///
    /// The ingest hot path: each thread tiles `X·Wᵀ` through the
    /// 4-col-unrolled serial GEMM block and sweeps the tile with the
    /// operator's trig backend, accumulating straight into the partial.
    pub fn sketch_points_sum(&self, points: &[f64], weights: Option<&[f64]>) -> CVec {
        let n = self.n_dims();
        assert_eq!(points.len() % n, 0);
        let n_points = points.len() / n;
        let m = self.m();
        if n_points == 0 {
            return CVec::zeros(m);
        }
        let threads = parallel::default_threads();
        let trig = self.trig;
        let partials = parallel::parallel_map_ranges(n_points, threads, |range| {
            let mut acc = CVec::zeros(m);
            // Process rows in blocks so the X·Wᵀ tile stays in cache; the
            // tile buffer is reused across blocks.
            const BLOCK: usize = 256;
            let mut theta = vec![0.0; BLOCK.min(range.len()) * m];
            let mut lo = range.start;
            while lo < range.end {
                let hi = (lo + BLOCK).min(range.end);
                let rows = hi - lo;
                matmul_bt_block(
                    &points[lo * n..hi * n],
                    &self.w.data,
                    &mut theta[..rows * m],
                    0,
                    rows,
                    n,
                    m,
                );
                for (bi, row) in theta[..rows * m].chunks_exact(m).enumerate() {
                    match weights {
                        None => fastmath::accum_sweep(trig, row, &mut acc.re, &mut acc.im),
                        Some(w) => fastmath::accum_sweep_weighted(
                            trig,
                            row,
                            w[lo + bi],
                            &mut acc.re,
                            &mut acc.im,
                        ),
                    }
                }
                lo = hi;
            }
            acc
        });
        let mut z = CVec::zeros(m);
        for p in partials {
            z.axpy(1.0, &p);
        }
        z
    }
}

/// θ tile = X_blk · Wᵀ, flattened row-major (`rows × m`), through the same
/// 4-col-unrolled serial GEMM block as every other `X·Bᵀ` hot path (dots
/// accumulate in ascending-index order, so the values are bit-identical to
/// the naive per-row loop this replaced). Single-threaded: callers
/// parallelize over row ranges (also used by the quantized accumulator in
/// [`super::quantize`]).
pub(crate) fn x_blk_theta_into(points: &[f64], rows: usize, w: &Mat, out: &mut [f64]) {
    debug_assert_eq!(points.len(), rows * w.cols);
    debug_assert_eq!(out.len(), rows * w.rows);
    matmul_bt_block(points, &w.data, out, 0, rows, w.cols, w.rows);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::frequencies::FreqDist;
    use crate::testing::{self, gen, Config};
    use crate::util::rng::Rng;

    fn op(m: usize, n: usize, seed: u64) -> SketchOp {
        let mut rng = Rng::new(seed);
        SketchOp::new(FreqDist::adapted(1.0).draw(m, n, &mut rng))
    }

    #[test]
    fn atom_unit_modulus_and_norm() {
        let o = op(64, 5, 1);
        let mut rng = Rng::new(2);
        let c = gen::vec_normal(&mut rng, 5);
        let a = o.atom(&c);
        for (r, i) in a.re.iter().zip(&a.im) {
            assert!((r * r + i * i - 1.0).abs() < 1e-12);
        }
        assert!((a.norm2() - o.atom_norm()).abs() < 1e-9);
    }

    #[test]
    fn sketch_single_point_equals_atom() {
        let o = op(32, 4, 3);
        let mut rng = Rng::new(4);
        let x = gen::vec_normal(&mut rng, 4);
        let z = o.sketch_points(&x, None);
        let a = o.atom(&x);
        testing::all_close(&z.re, &a.re, 1e-12).unwrap();
        testing::all_close(&z.im, &a.im, 1e-12).unwrap();
    }

    #[test]
    fn prop_sketch_is_linear_in_measure() {
        testing::check("sketch linearity", Config::default().cases(16).max_size(30), |rng, size| {
            let n = 1 + rng.below(6);
            let o = op(24, n, rng.next_u64());
            let n1 = 1 + rng.below(size);
            let n2 = 1 + rng.below(size);
            let xs1 = gen::mat_normal(rng, n1, n);
            let xs2 = gen::mat_normal(rng, n2, n);
            // Sketch of the union with uniform 1/(n1+n2) weights equals the
            // weighted average of the two sketches.
            let mut all = xs1.clone();
            all.extend_from_slice(&xs2);
            let z_all = o.sketch_points(&all, None);
            let z1 = o.sketch_points(&xs1, None);
            let z2 = o.sketch_points(&xs2, None);
            let t = n1 as f64 / (n1 + n2) as f64;
            let mut mix = CVec::zeros(24);
            mix.axpy(t, &z1);
            mix.axpy(1.0 - t, &z2);
            testing::all_close(&z_all.re, &mix.re, 1e-10)?;
            testing::all_close(&z_all.im, &mix.im, 1e-10)
        });
    }

    #[test]
    fn prop_sketch_modulus_bounded_by_one() {
        testing::check("|z_j| <= 1", Config::default().cases(16).max_size(40), |rng, size| {
            let n = 1 + rng.below(5);
            let o = op(16, n, rng.next_u64());
            let pts = gen::mat_normal(rng, 1 + size, n);
            let z = o.sketch_points(&pts, None);
            for v in z.modulus() {
                if v > 1.0 + 1e-9 {
                    return Err(format!("modulus {v}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sketch_points_sum_is_raw_atom_sum() {
        let o = op(16, 3, 21);
        let mut rng = Rng::new(22);
        let pts = gen::mat_normal(&mut rng, 7, 3);
        let sum = o.sketch_points_sum(&pts, None);
        let mut manual = CVec::zeros(16);
        for l in 0..7 {
            manual.axpy(1.0, &o.atom(&pts[l * 3..(l + 1) * 3]));
        }
        testing::all_close(&sum.re, &manual.re, 1e-12).unwrap();
        testing::all_close(&sum.im, &manual.im, 1e-12).unwrap();
        // ... and the normalized entry point is exactly sum / N.
        let z = o.sketch_points(&pts, None);
        let mut scaled = sum.clone();
        scaled.scale(1.0 / 7.0);
        assert_eq!(z.re, scaled.re);
        assert_eq!(z.im, scaled.im);
    }

    #[test]
    fn fast_trig_sketch_tracks_exact() {
        use crate::util::fastmath::TrigBackend;
        let mut rng = Rng::new(30);
        let w = FreqDist::adapted(1.0).draw(32, 4, &mut rng);
        let exact = SketchOp::new(w.clone());
        let fast = SketchOp::with_trig(w, TrigBackend::Fast);
        assert_eq!(exact.trig(), TrigBackend::Exact);
        assert_eq!(fast.trig(), TrigBackend::Fast);
        let pts = gen::mat_normal(&mut rng, 200, 4);
        let ze = exact.sketch_points(&pts, None);
        let zf = fast.sketch_points(&pts, None);
        // ≤ 2 ULP per trig call ⇒ indistinguishable at sketch scale.
        testing::all_close(&zf.re, &ze.re, 1e-12).unwrap();
        testing::all_close(&zf.im, &ze.im, 1e-12).unwrap();
        // atoms and step-1 gradients dispatch on the backend too
        let c = gen::vec_normal(&mut rng, 4);
        let (ae, af) = (exact.atom(&c), fast.atom(&c));
        testing::all_close(&af.re, &ae.re, 1e-13).unwrap();
        let r = CVec::from_parts(gen::vec_normal(&mut rng, 32), gen::vec_normal(&mut rng, 32));
        let (ve, ge) = exact.step1_value_grad(&c, &r);
        let (vf, gf) = fast.step1_value_grad(&c, &r);
        testing::close(vf, ve, 1e-10).unwrap();
        testing::all_close(&gf, &ge, 1e-10).unwrap();
    }

    #[test]
    fn weighted_sketch_matches_manual() {
        let o = op(16, 3, 7);
        let mut rng = Rng::new(8);
        let pts = gen::mat_normal(&mut rng, 5, 3);
        let w = [0.5, 0.2, 0.1, 0.1, 0.1];
        let z = o.sketch_points(&pts, Some(&w));
        let mut manual = CVec::zeros(16);
        for l in 0..5 {
            let a = o.atom(&pts[l * 3..(l + 1) * 3]);
            manual.axpy(w[l], &a);
        }
        testing::all_close(&z.re, &manual.re, 1e-12).unwrap();
        testing::all_close(&z.im, &manual.im, 1e-12).unwrap();
    }

    #[test]
    fn step1_gradient_matches_finite_difference() {
        let o = op(48, 4, 9);
        let mut rng = Rng::new(10);
        let c = gen::vec_normal(&mut rng, 4);
        let r = CVec::from_parts(gen::vec_normal(&mut rng, 48), gen::vec_normal(&mut rng, 48));
        let (f0, g) = o.step1_value_grad(&c, &r);
        let eps = 1e-6;
        for d in 0..4 {
            let mut cp = c.clone();
            cp[d] += eps;
            let (fp, _) = o.step1_value_grad(&cp, &r);
            let fd = (fp - f0) / eps;
            assert!((fd - g[d]).abs() < 1e-4 * (1.0 + g[d].abs()), "dim {d}: fd={fd} g={}", g[d]);
        }
    }

    #[test]
    fn step5_gradients_match_finite_difference() {
        let o = op(32, 3, 11);
        let mut rng = Rng::new(12);
        let kk = 3;
        let c = Mat::from_vec(kk, 3, gen::mat_normal(&mut rng, kk, 3));
        let alpha = vec![0.5, 0.3, 0.2];
        let z_hat = CVec::from_parts(gen::vec_normal(&mut rng, 32), gen::vec_normal(&mut rng, 32));
        let (g0, gc, ga) = o.step5_value_grads(&z_hat, &c, &alpha);
        let eps = 1e-6;
        for k in 0..kk {
            // alpha
            let mut ap = alpha.clone();
            ap[k] += eps;
            let (gp, _, _) = o.step5_value_grads(&z_hat, &c, &ap);
            let fd = (gp - g0) / eps;
            assert!((fd - ga[k]).abs() < 1e-4 * (1.0 + ga[k].abs()), "alpha {k}: {fd} vs {}", ga[k]);
            // centroids
            for d in 0..3 {
                let mut cp = c.clone();
                *cp.at_mut(k, d) += eps;
                let (gp, _, _) = o.step5_value_grads(&z_hat, &cp, &alpha);
                let fd = (gp - g0) / eps;
                let an = gc.at(k, d);
                assert!((fd - an).abs() < 1e-4 * (1.0 + an.abs()), "c[{k},{d}]: {fd} vs {an}");
            }
        }
    }

    #[test]
    fn mixture_sketch_of_dirac_training_set() {
        // Sketch of dataset == mixture sketch when dataset is K repeated points.
        let o = op(20, 2, 13);
        let pts = vec![1.0, -1.0, 1.0, -1.0, 2.0, 0.5, 2.0, 0.5, 2.0, 0.5];
        let z = o.sketch_points(&pts, None);
        let c = Mat::from_vec(2, 2, vec![1.0, -1.0, 2.0, 0.5]);
        let mix = o.mixture_sketch(&c, &[0.4, 0.6]);
        testing::all_close(&z.re, &mix.re, 1e-12).unwrap();
        testing::all_close(&z.im, &mix.im, 1e-12).unwrap();
    }
}
