//! Dithered quantization of the Fourier sketch (QCKM).
//!
//! Following *Quantized Compressive K-Means* (Schellekens & Jacques), the
//! sketch stays useful when each per-point moment contribution is crushed
//! to a handful of bits. Every contribution `e^{-i ω_j^T x}` has real and
//! imaginary parts in `[-1, 1]`; each part is mapped onto a uniform grid of
//! `L = 2^b` levels by *stochastically rounding* between the two
//! neighbouring levels, using a dither `u ~ U[0, 1)` drawn from a
//! provenance-derived RNG stream:
//!
//! ```text
//! code = ⌊ (v + 1)/Δ + u ⌋,   Δ = 2/(L − 1),   level(code) = −1 + Δ·code
//! ```
//!
//! Because `E_u[⌊t + u⌋] = t` exactly, `E[level(code)] = v`: dequantization
//! is *unbiased* with no decoder-side knowledge of the dithers, and the
//! per-point error has variance at most `Δ²/4`, which averages away at rate
//! `1/N` across the dataset. The decoder therefore consumes a debiased
//! [`CVec`] through the existing engine kernels unchanged.
//!
//! The accumulator sums the integer codes, so shard merging is *exact*
//! (associative and commutative in `u64` arithmetic — no floating-point
//! order effects at all, unlike the dense accumulator). Partials ship
//! bit-packed: a single-point quantum packs to `2m·b` bits — 64× below the
//! dense `2m`-double partial in 1-bit mode — and a `C`-point partial to
//! `2m·⌈log₂(C·(L−1)+1)⌉` bits (~10× for 4096-row chunks).
//!
//! Dither streams are keyed by `(dither seed, global row index)`, where the
//! dither seed derives from the operator provenance seed and a shard id
//! ([`dither_seed_for_shard`]). A quantized artifact is therefore
//! re-derivable from `(data, provenance, shard)` alone, regardless of
//! worker scheduling — and sites sketching *different* shards should use
//! distinct shard ids (`CkmBuilder::shard`) so their dither errors stay
//! independent and keep averaging away across a merge.

use crate::data::dataset::{Bounds, PointSource};
use crate::linalg::CVec;
use crate::sketch::operator::{x_blk_theta_into, SketchOp};
use crate::util::fastmath;
use crate::util::rng::Rng;

/// Salt mixed into the builder/operator seed to derive the dither stream
/// (kept distinct from the operator-draw salt so the two streams never
/// overlap).
const DITHER_SEED_SALT: u64 = 0xD117_4E5E_EDC0_DE26;

/// Per-row stream decorrelation constant (odd ⇒ bijective over u64).
const ROW_STREAM_MUL: u64 = 0x9E37_79B9_7F4A_7C15;

/// Per-shard stream decorrelation constant (odd ⇒ bijective over u64).
const SHARD_STREAM_MUL: u64 = 0xBF58_476D_1CE4_E5B9;

/// Derive the dither-stream seed from the operator provenance seed
/// (shard 0 — single-site sketching).
pub fn dither_seed_for(op_seed: u64) -> u64 {
    dither_seed_for_shard(op_seed, 0)
}

/// Dither-stream seed for shard `shard` of a multi-site sketch. Each site
/// numbers its rows from 0, so sites sharing a shard id would reuse the
/// same per-row dithers and their quantization errors would correlate
/// instead of averaging away in the merge; distinct shard ids give every
/// site an independent stream while staying re-derivable from
/// `(provenance, shard)`.
pub fn dither_seed_for_shard(op_seed: u64, shard: u64) -> u64 {
    (op_seed ^ DITHER_SEED_SALT).wrapping_add(shard.wrapping_mul(SHARD_STREAM_MUL))
}

/// The dither RNG for one global row of the dataset. Keying by row index
/// (not by draw order) keeps the quantized sketch independent of chunking
/// and worker scheduling.
fn row_rng(dither_seed: u64, global_row: usize) -> Rng {
    Rng::new(dither_seed ^ (global_row as u64).wrapping_mul(ROW_STREAM_MUL))
}

/// How many bits each sketch component's per-point contribution keeps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantizationMode {
    /// One bit per component: `{−1, +1}` (the QCKM headline regime).
    OneBit,
    /// `b` bits per component: `2^b` uniform levels over `[−1, 1]`.
    Bits(u8),
}

impl QuantizationMode {
    /// Canonical form: `Bits(1)` is the same quantizer as `OneBit`.
    pub fn normalized(self) -> QuantizationMode {
        match self {
            QuantizationMode::Bits(1) => QuantizationMode::OneBit,
            other => other,
        }
    }

    /// Bits per component.
    pub fn bits(&self) -> u32 {
        match self {
            QuantizationMode::OneBit => 1,
            QuantizationMode::Bits(b) => *b as u32,
        }
    }

    /// Number of quantization levels `L = 2^bits`.
    pub fn levels(&self) -> u64 {
        1u64 << self.bits()
    }

    /// Grid pitch `Δ = 2/(L − 1)` over `[−1, 1]`.
    pub fn delta(&self) -> f64 {
        2.0 / (self.levels() - 1) as f64
    }

    /// Builder-time validation (typed errors live in the api layer).
    pub fn validate(&self) -> Result<(), String> {
        match self {
            QuantizationMode::OneBit => Ok(()),
            QuantizationMode::Bits(b) if (1..=16).contains(b) => Ok(()),
            QuantizationMode::Bits(b) => {
                Err(format!("quantization bits must be in 1..=16, got {b}"))
            }
        }
    }

    pub fn name(&self) -> String {
        format!("{}-bit", self.bits())
    }

    /// Parse `1bit`/`1-bit`/`onebit` or `<b>bit`/`<b>-bit`.
    pub fn parse(s: &str) -> anyhow::Result<QuantizationMode> {
        let lower = s.to_ascii_lowercase();
        if matches!(lower.as_str(), "1bit" | "1-bit" | "onebit" | "one-bit") {
            return Ok(QuantizationMode::OneBit);
        }
        let digits = lower
            .strip_suffix("-bit")
            .or_else(|| lower.strip_suffix("bit"))
            .unwrap_or(&lower);
        let b: u8 = digits
            .parse()
            .map_err(|_| anyhow::anyhow!("unknown quantization mode '{s}' (try 1bit..16bit)"))?;
        let mode = QuantizationMode::Bits(b).normalized();
        mode.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(mode)
    }
}

/// Stochastically round one component value `v ∈ [−1, 1]` to a level code
/// in `0..levels`, using the dither `u ∈ [0, 1)`. Unbiased:
/// `E_u[−1 + Δ·code] = v`.
pub fn quantize_component(v: f64, u: f64, mode: QuantizationMode) -> u64 {
    let t = (v + 1.0) / mode.delta() + u;
    (t.floor() as i64).clamp(0, mode.levels() as i64 - 1) as u64
}

/// Dequantize summed level codes (re components then im, `2m` entries)
/// into the *unnormalized* complex sums the dense accumulator would hold:
/// `Σ_points (−1 + Δ·code) = Δ·Σcode − count`, per component.
pub fn dequantize_level_sums(mode: QuantizationMode, level_sums: &[u64], count: usize) -> CVec {
    assert_eq!(level_sums.len() % 2, 0);
    let m = level_sums.len() / 2;
    let delta = mode.delta();
    let cnt = count as f64;
    let mut z = CVec::zeros(m);
    for j in 0..m {
        z.re[j] = delta * level_sums[j] as f64 - cnt;
        z.im[j] = delta * level_sums[m + j] as f64 - cnt;
    }
    z
}

/// Bits needed per packed component for a partial over `count` points:
/// the summed code is at most `count·(L−1)`.
pub fn width_for(count: usize, mode: QuantizationMode) -> u32 {
    let max = (count as u128) * (mode.levels() as u128 - 1);
    (128 - max.leading_zeros()).max(1)
}

/// Pack `vals` (each `< 2^width`) LSB-first into u64 words.
pub fn pack_values(vals: &[u64], width: u32) -> Vec<u64> {
    assert!((1..=64).contains(&width), "pack width {width} out of range");
    let total_bits = vals.len() * width as usize;
    let mut words = vec![0u64; total_bits.div_ceil(64)];
    let mut bit = 0usize;
    for &v in vals {
        debug_assert!(width == 64 || v < (1u64 << width), "value {v} exceeds width {width}");
        let w = bit / 64;
        let off = bit % 64;
        words[w] |= v << off;
        let spill = 64 - off;
        if (width as usize) > spill {
            words[w + 1] |= v >> spill;
        }
        bit += width as usize;
    }
    words
}

/// Inverse of [`pack_values`]: unpack `n` values of `width` bits. Returns
/// `None` when `words` is not exactly the packed length for `(n, width)`.
pub fn unpack_values(words: &[u64], width: u32, n: usize) -> Option<Vec<u64>> {
    if !(1..=64).contains(&width) || words.len() != (n * width as usize).div_ceil(64) {
        return None;
    }
    let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
    let mut out = Vec::with_capacity(n);
    let mut bit = 0usize;
    for _ in 0..n {
        let w = bit / 64;
        let off = bit % 64;
        let mut v = words[w] >> off;
        let spill = 64 - off;
        if (width as usize) > spill {
            v |= words[w + 1] << spill;
        }
        out.push(v & mask);
        bit += width as usize;
    }
    Some(out)
}

/// Hex encoding of packed words (little-endian bytes, lowercase) — the
/// artifact payload and the coordinator wire format.
pub fn words_to_hex(words: &[u64]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut s = String::with_capacity(words.len() * 16);
    for w in words {
        for b in w.to_le_bytes() {
            s.push(HEX[(b >> 4) as usize] as char);
            s.push(HEX[(b & 0xf) as usize] as char);
        }
    }
    s
}

/// A rejected packed-hex payload: every variant names exactly what was
/// wrong, so a corrupt artifact fails loudly instead of parsing to a
/// silently truncated or re-interpreted word vector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum HexPayloadError {
    /// Hex encodes whole bytes; an odd character count cannot.
    OddLength { len: usize },
    /// Whole bytes but not whole little-endian u64 words — a truncated or
    /// padded payload, never a shorter valid one.
    NotWordAligned { len: usize },
    /// A character outside `[0-9a-f]` at `pos` (0-based). Uppercase hex is
    /// rejected too: [`words_to_hex`] emits lowercase only, so accepting
    /// `A`–`F` would let two different strings decode to the same words
    /// and break canonical round-trip checks.
    BadDigit { pos: usize, byte: u8 },
}

impl std::fmt::Display for HexPayloadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HexPayloadError::OddLength { len } => {
                write!(f, "packed payload length {len} is odd (hex encodes whole bytes)")
            }
            HexPayloadError::NotWordAligned { len } => {
                write!(f, "packed payload length {len} is not a multiple of 16 (whole u64 words)")
            }
            HexPayloadError::BadDigit { pos, byte } => {
                if byte.is_ascii_graphic() {
                    write!(
                        f,
                        "bad hex digit '{}' at offset {pos} (lowercase [0-9a-f] only)",
                        *byte as char
                    )
                } else {
                    write!(f, "bad hex byte 0x{byte:02x} at offset {pos} (lowercase [0-9a-f] only)")
                }
            }
        }
    }
}

impl std::error::Error for HexPayloadError {}

/// Inverse of [`words_to_hex`]. Strictly canonical: only lowercase
/// `[0-9a-f]`, only whole-word lengths — anything else is a typed
/// [`HexPayloadError`], never a panic and never a shortened result
/// (`Ok(words)` always has exactly `s.len() / 16` entries).
pub fn hex_to_words(s: &str) -> Result<Vec<u64>, HexPayloadError> {
    if s.len() % 2 != 0 {
        return Err(HexPayloadError::OddLength { len: s.len() });
    }
    if s.len() % 16 != 0 {
        return Err(HexPayloadError::NotWordAligned { len: s.len() });
    }
    let bytes = s.as_bytes();
    let nibble = |pos: usize| -> Result<u64, HexPayloadError> {
        match bytes[pos] {
            b @ b'0'..=b'9' => Ok((b - b'0') as u64),
            b @ b'a'..=b'f' => Ok((b - b'a' + 10) as u64),
            byte => Err(HexPayloadError::BadDigit { pos, byte }),
        }
    };
    let mut words = Vec::with_capacity(s.len() / 16);
    for word_start in (0..s.len()).step_by(16) {
        let mut w = 0u64;
        for i in 0..8 {
            let pos = word_start + 2 * i;
            let byte = (nibble(pos)? << 4) | nibble(pos + 1)?;
            w |= byte << (8 * i);
        }
        words.push(w);
    }
    Ok(words)
}

/// The quantized counterpart of [`crate::sketch::SketchAccumulator`]:
/// per-component summed level codes + count + bounds. Merging adds
/// integers, so shard combination is bit-exact in any order.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedAccumulator {
    pub mode: QuantizationMode,
    /// Summed level codes: `m` re components, then `m` im components.
    pub level_sums: Vec<u64>,
    pub count: usize,
    pub bounds: Bounds,
    /// Provenance-derived dither-stream seed (see [`dither_seed_for`]).
    pub dither_seed: u64,
}

impl QuantizedAccumulator {
    pub fn new(m: usize, n_dims: usize, mode: QuantizationMode, dither_seed: u64) -> Self {
        QuantizedAccumulator {
            mode: mode.normalized(),
            level_sums: vec![0; 2 * m],
            count: 0,
            bounds: Bounds::empty(n_dims),
            dither_seed,
        }
    }

    pub fn m(&self) -> usize {
        self.level_sums.len() / 2
    }

    /// Absorb a row-major block of points whose first row is global row
    /// `row_offset` of the stream (the dither stream is keyed by global
    /// row, so chunked and whole-stream sketching agree exactly).
    pub fn update(&mut self, op: &SketchOp, points: &[f64], row_offset: usize) {
        let n = op.n_dims();
        assert_eq!(points.len() % n, 0);
        let m = op.m();
        assert_eq!(self.level_sums.len(), 2 * m, "operator m != accumulator m");
        let rows = points.len() / n;
        const BLOCK: usize = 256;
        // Reusable scratch: the X·Wᵀ θ tile (through the 4-col-unrolled
        // GEMM block) plus one row of sin/cos swept with the operator's
        // trig backend. The sweep is per-row, so the sin/cos values (and
        // therefore the integer codes) are invariant to chunking.
        let mut theta = vec![0.0; BLOCK.min(rows.max(1)) * m];
        let (mut sin_row, mut cos_row) = (vec![0.0; m], vec![0.0; m]);
        let mut lo = 0usize;
        while lo < rows {
            let hi = (lo + BLOCK).min(rows);
            let blk = hi - lo;
            x_blk_theta_into(&points[lo * n..hi * n], blk, &op.w, &mut theta[..blk * m]);
            for (bi, trow) in theta[..blk * m].chunks_exact(m).enumerate() {
                fastmath::sincos_sweep(op.trig(), trow, &mut sin_row, &mut cos_row);
                let mut dither = row_rng(self.dither_seed, row_offset + lo + bi);
                for j in 0..m {
                    self.level_sums[j] +=
                        quantize_component(cos_row[j], dither.uniform(), self.mode);
                    self.level_sums[m + j] +=
                        quantize_component(-sin_row[j], dither.uniform(), self.mode);
                }
            }
            lo = hi;
        }
        for r in 0..rows {
            self.bounds.update(&points[r * n..(r + 1) * n]);
        }
        self.count += rows;
    }

    /// Exact merge (associative, commutative — integer arithmetic).
    pub fn merge(&mut self, other: &QuantizedAccumulator) {
        assert_eq!(self.mode, other.mode, "quantization mode mismatch");
        assert_eq!(self.level_sums.len(), other.level_sums.len());
        assert_eq!(self.dither_seed, other.dither_seed, "dither stream mismatch");
        for (a, b) in self.level_sums.iter_mut().zip(&other.level_sums) {
            *a += b;
        }
        self.count += other.count;
        self.bounds.merge(&other.bounds);
    }

    /// Debiased *unnormalized* sums (the dense accumulator's `sum`
    /// equivalent): `Δ·Σcode − count` per component.
    pub fn dequantized_sum(&self) -> CVec {
        dequantize_level_sums(self.mode, &self.level_sums, self.count)
    }

    /// Debiased normalized sketch `ẑ` — what CLOMPR decodes.
    pub fn finalize(&self) -> CVec {
        crate::sketch::streaming::normalize_sum(&self.dequantized_sum(), self.count)
    }

    /// Bit-pack for shipping (the coordinator's worker→leader payload).
    pub fn pack(&self) -> PackedPartial {
        let width = width_for(self.count, self.mode);
        PackedPartial {
            mode: self.mode,
            dither_seed: self.dither_seed,
            m: self.m(),
            count: self.count,
            bounds: self.bounds.clone(),
            width,
            words: pack_values(&self.level_sums, width),
        }
    }
}

/// A bit-packed quantized partial: what a sketching worker ships to the
/// leader, and the payload layout of a v2 quantized artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedPartial {
    pub mode: QuantizationMode,
    pub dither_seed: u64,
    pub m: usize,
    pub count: usize,
    pub bounds: Bounds,
    /// Bits per packed component (`width_for(count, mode)`).
    pub width: u32,
    /// `2m` component sums packed LSB-first into u64 words.
    pub words: Vec<u64>,
}

impl PackedPartial {
    /// Payload size in bytes (the bandwidth number).
    pub fn payload_bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// Unpack back into a mergeable accumulator. Fails on a malformed
    /// payload (wrong length, codes exceeding `count·(L−1)`).
    pub fn unpack(&self) -> Result<QuantizedAccumulator, String> {
        if self.width != width_for(self.count, self.mode) {
            return Err(format!(
                "packed width {} != canonical width {} for count {}",
                self.width,
                width_for(self.count, self.mode),
                self.count
            ));
        }
        let level_sums = unpack_values(&self.words, self.width, 2 * self.m)
            .ok_or_else(|| "packed payload length mismatch".to_string())?;
        let max = self.count as u64 * (self.mode.levels() - 1);
        if level_sums.iter().any(|&v| v > max) {
            return Err(format!("packed code sum exceeds count*(levels-1) = {max}"));
        }
        if pack_values(&level_sums, self.width) != self.words {
            return Err("non-canonical packed payload (trailing bits set)".to_string());
        }
        Ok(QuantizedAccumulator {
            mode: self.mode,
            level_sums,
            count: self.count,
            bounds: self.bounds.clone(),
            dither_seed: self.dither_seed,
        })
    }
}

/// Sequential quantized counterpart of
/// [`crate::sketch::streaming::sketch_source`]: drain a [`PointSource`]
/// through a quantized accumulator with global row numbering.
pub fn quantized_sketch_source(
    op: &SketchOp,
    source: &mut dyn PointSource,
    chunk_rows: usize,
    mode: QuantizationMode,
    dither_seed: u64,
) -> QuantizedAccumulator {
    let n = op.n_dims();
    assert_eq!(source.n_dims(), n, "source dims != operator dims");
    let mut acc = QuantizedAccumulator::new(op.m(), n, mode, dither_seed);
    let mut buf = vec![0.0; chunk_rows.max(1) * n];
    let mut next_row = 0usize;
    loop {
        let rows = source.next_chunk(&mut buf);
        if rows == 0 {
            break;
        }
        acc.update(op, &buf[..rows * n], next_row);
        next_row += rows;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::SliceSource;
    use crate::sketch::frequencies::FreqDist;
    use crate::sketch::SketchAccumulator;
    use crate::testing::{self, gen, Config};

    fn op(m: usize, n: usize, seed: u64) -> SketchOp {
        let mut rng = Rng::new(seed);
        SketchOp::new(FreqDist::adapted(1.0).draw(m, n, &mut rng))
    }

    #[test]
    fn mode_arithmetic() {
        assert_eq!(QuantizationMode::OneBit.levels(), 2);
        assert_eq!(QuantizationMode::OneBit.delta(), 2.0);
        assert_eq!(QuantizationMode::Bits(3).levels(), 8);
        assert!((QuantizationMode::Bits(3).delta() - 2.0 / 7.0).abs() < 1e-15);
        assert_eq!(QuantizationMode::Bits(1).normalized(), QuantizationMode::OneBit);
        assert!(QuantizationMode::Bits(0).validate().is_err());
        assert!(QuantizationMode::Bits(17).validate().is_err());
        assert_eq!(QuantizationMode::parse("1bit").unwrap(), QuantizationMode::OneBit);
        assert_eq!(QuantizationMode::parse("4-bit").unwrap(), QuantizationMode::Bits(4));
        assert!(QuantizationMode::parse("40bit").is_err());
        assert!(QuantizationMode::parse("garbage").is_err());
    }

    #[test]
    fn quantize_component_endpoints_and_unbiasedness() {
        let mode = QuantizationMode::OneBit;
        // v = ±1 quantizes deterministically regardless of dither.
        assert_eq!(quantize_component(1.0, 0.999, mode), 1);
        assert_eq!(quantize_component(-1.0, 0.0, mode), 0);
        // Interior value: empirical mean of the level matches v.
        let v = 0.3;
        let mut rng = Rng::new(9);
        let n = 200_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let code = quantize_component(v, rng.uniform(), mode);
            acc += -1.0 + mode.delta() * code as f64;
        }
        let mean = acc / n as f64;
        assert!((mean - v).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn prop_pack_unpack_roundtrip() {
        let cfg = Config::default().cases(48).max_size(80);
        testing::check("pack/unpack roundtrip", cfg, |rng, size| {
            let width = 1 + rng.below(24) as u32;
            let n = 1 + size;
            let mask = (1u64 << width) - 1;
            let vals: Vec<u64> = (0..n).map(|_| rng.next_u64() & mask).collect();
            let words = pack_values(&vals, width);
            if words.len() != (n * width as usize).div_ceil(64) {
                return Err("wrong packed length".into());
            }
            let back = unpack_values(&words, width, n).ok_or("unpack refused")?;
            if back != vals {
                return Err("values corrupted".into());
            }
            // hex encoding round-trips too
            if hex_to_words(&words_to_hex(&words)).as_deref() != Ok(&words[..]) {
                return Err("hex corrupted".into());
            }
            Ok(())
        });
    }

    #[test]
    fn hex_rejections_are_typed() {
        assert_eq!(hex_to_words("abc"), Err(HexPayloadError::OddLength { len: 3 }));
        assert_eq!(hex_to_words("abcdef"), Err(HexPayloadError::NotWordAligned { len: 6 }));
        // Uppercase is valid hex but not *our* hex: words_to_hex emits
        // lowercase only, so the overlong/aliased spelling is rejected.
        assert_eq!(
            hex_to_words("00000000000000AB"),
            Err(HexPayloadError::BadDigit { pos: 14, byte: b'A' })
        );
        assert_eq!(
            hex_to_words("000000000000000g"),
            Err(HexPayloadError::BadDigit { pos: 15, byte: b'g' })
        );
        assert_eq!(hex_to_words(""), Ok(vec![]));
    }

    #[test]
    fn prop_corrupt_hex_never_panics_or_truncates() {
        let cfg = Config::default().cases(64).max_size(64);
        testing::check("corrupt hex is rejected, never truncated", cfg, |rng, size| {
            // Start from a valid payload, then corrupt it one of several
            // ways; whatever comes back must be a typed error or a
            // full-length decode — never a panic, never fewer words.
            let words: Vec<u64> = (0..1 + size / 8).map(|_| rng.next_u64()).collect();
            let mut s = words_to_hex(&words).into_bytes();
            match rng.below(4) {
                0 => {
                    // truncate at an arbitrary boundary
                    let cut = rng.below(s.len() + 1);
                    s.truncate(cut);
                }
                1 => {
                    // flip one byte to arbitrary ASCII
                    let pos = rng.below(s.len());
                    s[pos] = (rng.below(94) + 33) as u8;
                }
                2 => {
                    // uppercase one digit (aliased spelling of same value)
                    let pos = rng.below(s.len());
                    s[pos] = s[pos].to_ascii_uppercase();
                }
                _ => {
                    // append garbage
                    let extra = 1 + rng.below(17);
                    for _ in 0..extra {
                        s.push((rng.below(94) + 33) as u8);
                    }
                }
            }
            let s = String::from_utf8(s).map_err(|e| e.to_string())?;
            match hex_to_words(&s) {
                Ok(decoded) => {
                    // Only reachable when the corruption happened to keep
                    // the string canonical (e.g. uppercasing '7'); the
                    // decode must still cover every word.
                    if decoded.len() != s.len() / 16 {
                        return Err(format!(
                            "silent truncation: {} chars -> {} words",
                            s.len(),
                            decoded.len()
                        ));
                    }
                    if words_to_hex(&decoded) != s {
                        return Err("accepted a non-canonical payload".into());
                    }
                }
                Err(
                    HexPayloadError::OddLength { .. }
                    | HexPayloadError::NotWordAligned { .. }
                    | HexPayloadError::BadDigit { .. },
                ) => {}
            }
            Ok(())
        });
    }

    #[test]
    fn prop_merge_commutative_associative_exact() {
        let cfg = Config::default().cases(16).max_size(40);
        testing::check("quantized merge exact", cfg, |rng, size| {
            let n = 1 + rng.below(4);
            let o = op(12, n, rng.next_u64());
            let total = 3 + size;
            let pts = gen::mat_normal(rng, total, n);
            let seed = rng.next_u64();
            let c1 = 1 + rng.below(total - 2);
            let c2 = c1 + 1 + rng.below(total - c1 - 1);
            let mut parts = Vec::new();
            for (s, e) in [(0, c1), (c1, c2), (c2, total)] {
                let mut acc = QuantizedAccumulator::new(12, n, QuantizationMode::OneBit, seed);
                acc.update(&o, &pts[s * n..e * n], s);
                parts.push(acc);
            }
            let mut left = parts[0].clone();
            left.merge(&parts[1]);
            left.merge(&parts[2]);
            let mut right = parts[2].clone();
            right.merge(&parts[1]);
            right.merge(&parts[0]);
            let mut whole = QuantizedAccumulator::new(12, n, QuantizationMode::OneBit, seed);
            whole.update(&o, &pts, 0);
            // Integer state: merge order cannot matter, bit for bit.
            if left != right {
                return Err("merge not commutative/associative".into());
            }
            if left != whole {
                return Err("sharded != whole-stream".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_packed_partial_roundtrip() {
        let cfg = Config::default().cases(16).max_size(50);
        testing::check("packed partial roundtrip", cfg, |rng, size| {
            let n = 1 + rng.below(3);
            let o = op(8, n, rng.next_u64());
            let pts = gen::mat_normal(rng, 1 + size, n);
            let mode = if rng.below(2) == 0 {
                QuantizationMode::OneBit
            } else {
                QuantizationMode::Bits(4)
            };
            let mut acc = QuantizedAccumulator::new(8, n, mode, rng.next_u64());
            acc.update(&o, &pts, 0);
            let packed = acc.pack();
            let back = packed.unpack().map_err(|e| e.to_string())?;
            if back != acc {
                return Err("pack/unpack changed the accumulator".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dequantization_tracks_dense_sketch() {
        // RMS error between the debiased quantized sketch and the dense
        // sketch is bounded by the stochastic-rounding noise floor
        // Δ/(2·√count) (up to a generous constant).
        testing::check("dequantization RMS", Config::default().cases(12).max_size(8), |rng, size| {
            let n = 1 + rng.below(3);
            let o = op(16, n, rng.next_u64());
            let count = 100 * (1 + size);
            let pts = gen::mat_normal(rng, count, n);
            for mode in [QuantizationMode::OneBit, QuantizationMode::Bits(4)] {
                let mut dense = SketchAccumulator::new(16, n);
                dense.update(&o, &pts);
                let zd = dense.finalize();
                let mut q = QuantizedAccumulator::new(16, n, mode, rng.next_u64());
                q.update(&o, &pts, 0);
                let zq = q.finalize();
                let mut se = 0.0;
                for j in 0..16 {
                    se += (zq.re[j] - zd.re[j]).powi(2) + (zq.im[j] - zd.im[j]).powi(2);
                }
                let rms = (se / 32.0).sqrt();
                let floor = mode.delta() / (2.0 * (count as f64).sqrt());
                if rms > 3.0 * floor + 1e-3 {
                    return Err(format!(
                        "{}: rms {rms:.4} above noise floor {floor:.4}",
                        mode.name()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn unbiased_over_dither_streams() {
        // Averaging the quantized sketch of a tiny fixed dataset over many
        // independent dither streams converges to the dense sketch — the
        // unbiasedness property itself, not just the concentration bound.
        let n = 3;
        let o = op(8, n, 5);
        let mut rng = Rng::new(6);
        let pts = gen::mat_normal(&mut rng, 10, n);
        let mut dense = SketchAccumulator::new(8, n);
        dense.update(&o, &pts);
        let zd = dense.finalize();
        let mode = QuantizationMode::Bits(3);
        let streams = 256;
        let mut avg = CVec::zeros(8);
        for s in 0..streams {
            let mut q = QuantizedAccumulator::new(8, n, mode, 1000 + s as u64);
            q.update(&o, &pts, 0);
            avg.axpy(1.0 / streams as f64, &q.finalize());
        }
        // per-stream component std ≤ Δ/(2√10) ≈ 0.045; over 256 streams the
        // mean has std ≤ 0.0029 — 0.02 is a ~7σ band.
        testing::all_close(&avg.re, &zd.re, 0.02).unwrap();
        testing::all_close(&avg.im, &zd.im, 0.02).unwrap();
    }

    #[test]
    fn streamed_equals_blocked_update() {
        // Chunked streaming with global row numbering equals one update.
        let n = 4;
        let o = op(16, n, 11);
        let mut rng = Rng::new(12);
        let pts = gen::mat_normal(&mut rng, 103, n);
        let mut src = SliceSource::new(&pts, n);
        let streamed =
            quantized_sketch_source(&o, &mut src, 16, QuantizationMode::OneBit, 77);
        let mut whole = QuantizedAccumulator::new(16, n, QuantizationMode::OneBit, 77);
        whole.update(&o, &pts, 0);
        assert_eq!(streamed, whole);
        assert_eq!(streamed.count, 103);
        assert!(streamed.bounds.is_valid());
    }

    #[test]
    fn width_for_tracks_count_and_levels() {
        assert_eq!(width_for(0, QuantizationMode::OneBit), 1);
        assert_eq!(width_for(1, QuantizationMode::OneBit), 1);
        assert_eq!(width_for(2, QuantizationMode::OneBit), 2);
        assert_eq!(width_for(4096, QuantizationMode::OneBit), 13);
        assert_eq!(width_for(1, QuantizationMode::Bits(8)), 8);
    }
}
