//! Frequency distributions Λ for the sketching operator.
//!
//! Following Keriven et al. (the sketching companion paper [5]), a
//! frequency is drawn as `ω = (R/σ)·φ` with `φ` uniform on the unit sphere
//! and the dimensionless radius `R` drawn from one of:
//!
//! - **Gaussian**: `ω ~ N(0, Id/σ²)`, i.e. `R` is a chi-distributed radius;
//! - **FoldedGaussian** radius: `R ~ |N(0, 1)|`;
//! - **AdaptedRadius** (the paper's default): density
//!   `p(R) ∝ (R² + R⁴/4)^{1/2} · e^{−R²/2}`, a heuristic that maximizes the
//!   expected variation of a unit-Gaussian's characteristic function at the
//!   sampled frequency.
//!
//! Radial laws are sampled by inverse-CDF on a dense tabulated grid.

use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Which radial law to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RadiusKind {
    Gaussian,
    FoldedGaussian,
    AdaptedRadius,
}

impl RadiusKind {
    pub fn parse(s: &str) -> anyhow::Result<RadiusKind> {
        match s {
            "gaussian" => Ok(RadiusKind::Gaussian),
            "folded" | "folded-gaussian" => Ok(RadiusKind::FoldedGaussian),
            "adapted" | "adapted-radius" | "ar" => Ok(RadiusKind::AdaptedRadius),
            _ => anyhow::bail!("unknown radius kind '{s}' (gaussian|folded|adapted)"),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            RadiusKind::Gaussian => "gaussian",
            RadiusKind::FoldedGaussian => "folded",
            RadiusKind::AdaptedRadius => "adapted",
        }
    }
}

/// A frequency distribution: radial law + scale σ² (variance proxy of the
/// data clusters; frequencies live at scale 1/σ).
#[derive(Clone, Debug)]
pub struct FreqDist {
    pub kind: RadiusKind,
    pub sigma2: f64,
}

impl FreqDist {
    pub fn new(kind: RadiusKind, sigma2: f64) -> FreqDist {
        assert!(sigma2 > 0.0, "sigma2 must be positive");
        FreqDist { kind, sigma2 }
    }

    /// Paper default: adapted radius.
    pub fn adapted(sigma2: f64) -> FreqDist {
        FreqDist::new(RadiusKind::AdaptedRadius, sigma2)
    }

    /// Draw an `m × n` frequency matrix `W` (rows are frequencies ω_j).
    pub fn draw(&self, m: usize, n_dims: usize, rng: &mut Rng) -> Mat {
        let sigma = self.sigma2.sqrt();
        let sampler = RadiusSampler::new(self.kind, n_dims);
        let mut w = Mat::zeros(m, n_dims);
        for j in 0..m {
            let dir = rng.unit_vector(n_dims);
            let r = sampler.sample(rng) / sigma;
            for (d, &u) in dir.iter().enumerate() {
                *w.at_mut(j, d) = r * u;
            }
        }
        w
    }
}

/// Inverse-CDF sampler for the dimensionless radius laws.
pub struct RadiusSampler {
    grid: Vec<f64>,
    cdf: Vec<f64>,
}

const GRID_N: usize = 4096;
const GRID_MAX: f64 = 10.0;

impl RadiusSampler {
    pub fn new(kind: RadiusKind, n_dims: usize) -> RadiusSampler {
        let mut grid = Vec::with_capacity(GRID_N);
        let mut pdf = Vec::with_capacity(GRID_N);
        for i in 0..GRID_N {
            let r = GRID_MAX * (i as f64 + 0.5) / GRID_N as f64;
            grid.push(r);
            pdf.push(match kind {
                // chi distribution with n_dims dof: p(r) ∝ r^{n-1} e^{-r²/2}
                RadiusKind::Gaussian => {
                    (n_dims as f64 - 1.0) * r.ln().max(-700.0) - 0.5 * r * r
                }
                RadiusKind::FoldedGaussian => -0.5 * r * r,
                RadiusKind::AdaptedRadius => {
                    0.5 * (r * r + r.powi(4) / 4.0).ln() - 0.5 * r * r
                }
            });
        }
        // log-pdf → normalized cdf
        let max_lp = pdf.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut cdf = Vec::with_capacity(GRID_N);
        let mut acc = 0.0;
        for lp in pdf {
            acc += (lp - max_lp).exp();
            cdf.push(acc);
        }
        for c in cdf.iter_mut() {
            *c /= acc;
        }
        RadiusSampler { grid, cdf }
    }

    /// Sample one radius by inverse CDF with linear interpolation.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let u = rng.uniform();
        let idx = self.cdf.partition_point(|&c| c < u);
        if idx == 0 {
            return self.grid[0] * (u / self.cdf[0]).min(1.0);
        }
        if idx >= GRID_N {
            return self.grid[GRID_N - 1];
        }
        let (c0, c1) = (self.cdf[idx - 1], self.cdf[idx]);
        let t = if c1 > c0 { (u - c0) / (c1 - c0) } else { 0.5 };
        self.grid[idx - 1] + t * (self.grid[idx] - self.grid[idx - 1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_radius(kind: RadiusKind, n_dims: usize, samples: usize) -> f64 {
        let s = RadiusSampler::new(kind, n_dims);
        let mut rng = Rng::new(10);
        (0..samples).map(|_| s.sample(&mut rng)).sum::<f64>() / samples as f64
    }

    #[test]
    fn folded_gaussian_mean() {
        // E|N(0,1)| = sqrt(2/π) ≈ 0.7979
        let m = mean_radius(RadiusKind::FoldedGaussian, 1, 40_000);
        assert!((m - 0.7979).abs() < 0.02, "mean={m}");
    }

    #[test]
    fn chi_mean_matches() {
        // chi with 3 dof: mean = 2·sqrt(2/π) ≈ 1.5958
        let m = mean_radius(RadiusKind::Gaussian, 3, 40_000);
        assert!((m - 1.5958).abs() < 0.03, "mean={m}");
    }

    #[test]
    fn adapted_radius_positive_and_bounded() {
        let s = RadiusSampler::new(RadiusKind::AdaptedRadius, 10);
        let mut rng = Rng::new(4);
        for _ in 0..1000 {
            let r = s.sample(&mut rng);
            assert!(r > 0.0 && r <= GRID_MAX);
        }
        // Mode of (r²+r⁴/4)^½ e^{-r²/2} is above 1 (pushed out vs folded)
        let m = mean_radius(RadiusKind::AdaptedRadius, 10, 40_000);
        assert!(m > 1.0 && m < 3.0, "mean={m}");
    }

    #[test]
    fn draw_shapes_and_scale() {
        let mut rng = Rng::new(5);
        // Larger sigma² → smaller frequencies (scale 1/σ).
        let w1 = FreqDist::adapted(1.0).draw(400, 6, &mut rng);
        let w2 = FreqDist::adapted(16.0).draw(400, 6, &mut rng);
        assert_eq!((w1.rows, w1.cols), (400, 6));
        let norm = |w: &Mat| {
            (0..w.rows)
                .map(|j| w.row(j).iter().map(|x| x * x).sum::<f64>().sqrt())
                .sum::<f64>()
                / w.rows as f64
        };
        let (n1, n2) = (norm(&w1), norm(&w2));
        assert!((n1 / n2 - 4.0).abs() < 0.5, "ratio={}", n1 / n2);
    }

    #[test]
    fn gaussian_kind_matches_normal_matrix() {
        // For the Gaussian kind, ω entries should be ~ N(0, 1/σ²): check
        // the empirical per-entry variance.
        let mut rng = Rng::new(6);
        let sigma2 = 4.0;
        let w = FreqDist::new(RadiusKind::Gaussian, sigma2).draw(2000, 5, &mut rng);
        let var = w.data.iter().map(|x| x * x).sum::<f64>() / w.data.len() as f64;
        assert!((var - 1.0 / sigma2).abs() < 0.03, "var={var}");
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(RadiusKind::parse("adapted").unwrap(), RadiusKind::AdaptedRadius);
        assert_eq!(RadiusKind::parse("gaussian").unwrap(), RadiusKind::Gaussian);
        assert_eq!(RadiusKind::parse("folded").unwrap(), RadiusKind::FoldedGaussian);
        assert!(RadiusKind::parse("nope").is_err());
    }
}
