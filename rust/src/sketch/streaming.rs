//! Mergeable streaming sketch accumulator.
//!
//! The sketch is linear in the empirical measure, so partial sketches over
//! shards merge exactly: the accumulator keeps *unnormalized* complex sums
//! plus the running point count and box bounds (the paper computes `l`, `u`
//! in the same single pass). This is the object coordinator workers ship
//! back to the leader.

use super::operator::SketchOp;
use crate::data::dataset::{Bounds, PointSource};
use crate::linalg::CVec;

/// Partial sketch state: unnormalized sums + count + bounds.
#[derive(Clone, Debug, PartialEq)]
pub struct SketchAccumulator {
    /// Unnormalized Σ e^{-iωx} over the points seen so far.
    pub sum: CVec,
    pub count: usize,
    pub bounds: Bounds,
}

impl SketchAccumulator {
    pub fn new(m: usize, n_dims: usize) -> SketchAccumulator {
        SketchAccumulator { sum: CVec::zeros(m), count: 0, bounds: Bounds::empty(n_dims) }
    }

    /// Absorb a row-major block of points (unweighted).
    pub fn update(&mut self, op: &SketchOp, points: &[f64]) {
        let n = op.n_dims();
        assert_eq!(points.len() % n, 0);
        let rows = points.len() / n;
        if rows == 0 {
            return;
        }
        // Raw unnormalized sums straight from the fused sweep — no
        // normalize-then-rescale churn (N·m wasted multiplies per chunk).
        let z = op.sketch_points_sum(points, None);
        self.sum.axpy(1.0, &z);
        for r in 0..rows {
            self.bounds.update(&points[r * n..(r + 1) * n]);
        }
        self.count += rows;
    }

    /// Exact merge of two partial sketches (associative, commutative).
    pub fn merge(&mut self, other: &SketchAccumulator) {
        assert_eq!(self.sum.len(), other.sum.len());
        self.sum.axpy(1.0, &other.sum);
        self.count += other.count;
        self.bounds.merge(&other.bounds);
    }

    /// Normalized sketch `ẑ = sum / count`.
    pub fn finalize(&self) -> CVec {
        normalize_sum(&self.sum, self.count)
    }
}

/// Normalize an unnormalized sketch sum: `ẑ = sum / count` (`count == 0`
/// leaves the zero vector untouched). Shared by the accumulator and the
/// durable [`crate::api::SketchArtifact`].
pub fn normalize_sum(sum: &CVec, count: usize) -> CVec {
    let mut z = sum.clone();
    if count > 0 {
        z.scale(1.0 / count as f64);
    }
    z
}

/// Drain a [`PointSource`] through an accumulator with the given chunk size
/// (rows per chunk). Returns the filled accumulator.
pub fn sketch_source(
    op: &SketchOp,
    source: &mut dyn PointSource,
    chunk_rows: usize,
) -> SketchAccumulator {
    let n = op.n_dims();
    assert_eq!(source.n_dims(), n, "source dims != operator dims");
    let mut acc = SketchAccumulator::new(op.m(), n);
    let mut buf = vec![0.0; chunk_rows.max(1) * n];
    loop {
        let rows = source.next_chunk(&mut buf);
        if rows == 0 {
            break;
        }
        acc.update(op, &buf[..rows * n]);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::SliceSource;
    use crate::sketch::frequencies::FreqDist;
    use crate::testing::{self, gen, Config};
    use crate::util::rng::Rng;

    fn op(m: usize, n: usize, seed: u64) -> SketchOp {
        let mut rng = Rng::new(seed);
        SketchOp::new(FreqDist::adapted(1.0).draw(m, n, &mut rng))
    }

    #[test]
    fn streaming_equals_batch() {
        let o = op(32, 4, 1);
        let mut rng = Rng::new(2);
        let pts = gen::mat_normal(&mut rng, 103, 4); // non-divisible by chunk
        let mut src = SliceSource::new(&pts, 4);
        let acc = sketch_source(&o, &mut src, 16);
        assert_eq!(acc.count, 103);
        let z_stream = acc.finalize();
        let z_batch = o.sketch_points(&pts, None);
        testing::all_close(&z_stream.re, &z_batch.re, 1e-10).unwrap();
        testing::all_close(&z_stream.im, &z_batch.im, 1e-10).unwrap();
    }

    #[test]
    fn prop_merge_associative_and_matches_whole(){
        testing::check("sketch merge", Config::default().cases(16).max_size(60), |rng, size| {
            let n = 1 + rng.below(5);
            let o = op(16, n, rng.next_u64());
            let total = 3 + size;
            let pts = gen::mat_normal(rng, total, n);
            // split into 3 shards
            let c1 = 1 + rng.below(total - 2);
            let c2 = c1 + 1 + rng.below(total - c1 - 1);
            let mut parts = Vec::new();
            for (s, e) in [(0, c1), (c1, c2), (c2, total)] {
                let mut acc = SketchAccumulator::new(16, n);
                acc.update(&o, &pts[s * n..e * n]);
                parts.push(acc);
            }
            // ((p0+p1)+p2) == (p0+(p1+p2)) == whole
            let mut left = parts[0].clone();
            left.merge(&parts[1]);
            left.merge(&parts[2]);
            let mut right = parts[2].clone();
            right.merge(&parts[1]);
            right.merge(&parts[0]);
            let mut whole = SketchAccumulator::new(16, n);
            whole.update(&o, &pts);
            let (zl, zr, zw) = (left.finalize(), right.finalize(), whole.finalize());
            testing::all_close(&zl.re, &zr.re, 1e-10)?;
            testing::all_close(&zl.re, &zw.re, 1e-10)?;
            testing::all_close(&zl.im, &zw.im, 1e-10)?;
            if left.bounds != whole.bounds {
                return Err("bounds mismatch".into());
            }
            if left.count != whole.count {
                return Err("count mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn empty_accumulator_finalizes_to_zero() {
        let acc = SketchAccumulator::new(8, 3);
        let z = acc.finalize();
        assert!(z.re.iter().all(|&v| v == 0.0));
        assert!(!acc.bounds.is_valid());
    }

    #[test]
    fn bounds_tracked_during_stream() {
        let o = op(8, 2, 5);
        let pts = vec![0.0, 5.0, -3.0, 1.0, 2.0, -7.0];
        let mut src = SliceSource::new(&pts, 2);
        let acc = sketch_source(&o, &mut src, 2);
        assert_eq!(acc.bounds.lo, vec![-3.0, -7.0]);
        assert_eq!(acc.bounds.hi, vec![2.0, 5.0]);
    }
}
