//! Frequency-scale (σ²) estimation from a small sub-sketch.
//!
//! The paper (step 1 of §3.3, detailed in the companion paper [5]) picks
//! the Adapted-radius scale σ² by sketching a small fraction of the data
//! at a spread of candidate radii and regressing the decay of the
//! empirical characteristic function. For data whose clusters have
//! intra-cluster variance σ², |E e^{-iωx}| ≈ envelope · e^{-σ²‖ω‖²/2};
//! averaging |ẑ_j| in radius rings and fitting
//! `-2·log|ẑ| ≈ σ²·R²` by weighted least squares through the origin
//! recovers σ². Weights favour rings with strong signal.

use super::frequencies::{FreqDist, RadiusKind};
use super::operator::SketchOp;
use crate::util::rng::Rng;

/// Configuration for σ² estimation.
#[derive(Clone, Debug)]
pub struct ScaleEstimator {
    /// Number of probe frequencies.
    pub m_probe: usize,
    /// Number of data points to subsample.
    pub n_subsample: usize,
    /// Number of radius rings for the regression.
    pub n_rings: usize,
    /// Initial σ² guess used to set the probe radius span.
    pub sigma2_init: f64,
}

impl Default for ScaleEstimator {
    fn default() -> Self {
        ScaleEstimator { m_probe: 500, n_subsample: 5000, n_rings: 20, sigma2_init: 1.0 }
    }
}

impl ScaleEstimator {
    /// Estimate σ² from (a subsample of) the points (row-major).
    pub fn estimate(&self, points: &[f64], n_dims: usize, rng: &mut Rng) -> f64 {
        assert!(n_dims > 0 && points.len() % n_dims == 0);
        let n_points = points.len() / n_dims;
        if n_points == 0 {
            return self.sigma2_init;
        }
        // Subsample rows.
        let take = self.n_subsample.min(n_points);
        let sub: Vec<f64> = if take == n_points {
            points.to_vec()
        } else {
            let idx = rng.sample_indices(n_points, take);
            let mut s = Vec::with_capacity(take * n_dims);
            for &i in &idx {
                s.extend_from_slice(&points[i * n_dims..(i + 1) * n_dims]);
            }
            s
        };

        // A crude pre-scale: use the mean coordinate variance so the probe
        // radii span the informative band even if sigma2_init is way off.
        let pre = coordinate_variance(&sub, n_dims).max(1e-12);

        // Probe frequencies: radii uniform in (0, r_max], directions random.
        // r_max chosen so e^{-σ²R²/2} reaches deep decay: R_max = 4/√pre.
        let r_max = 4.0 / pre.sqrt();
        let mut radii = Vec::with_capacity(self.m_probe);
        let mut w = crate::linalg::Mat::zeros(self.m_probe, n_dims);
        for j in 0..self.m_probe {
            let r = r_max * (j as f64 + 0.5) / self.m_probe as f64;
            radii.push(r);
            let dir = rng.unit_vector(n_dims);
            for d in 0..n_dims {
                *w.at_mut(j, d) = r * dir[d];
            }
        }
        let op = SketchOp::new(w);
        let z = op.sketch_points(&sub, None);
        let modulus = z.modulus();

        // Ring means of |z| over radius bins, then weighted LS through the
        // origin on (R², -2 log|z|): σ² = Σ w·R²·y / Σ w·R⁴.
        let mut num = 0.0;
        let mut den = 0.0;
        let per_ring = (self.m_probe / self.n_rings).max(1);
        for ring in 0..self.n_rings {
            let lo = ring * per_ring;
            let hi = ((ring + 1) * per_ring).min(self.m_probe);
            if lo >= hi {
                break;
            }
            let mean_mod: f64 =
                modulus[lo..hi].iter().sum::<f64>() / (hi - lo) as f64;
            let mean_r2: f64 =
                radii[lo..hi].iter().map(|r| r * r).sum::<f64>() / (hi - lo) as f64;
            // Ignore rings where the moment is noise-level (|z| small): the
            // subsample error is O(1/√take).
            let noise = 3.0 / (take as f64).sqrt();
            if mean_mod <= noise.max(0.05) {
                continue;
            }
            let y = -2.0 * mean_mod.ln();
            let weight = mean_mod; // favour high-signal rings
            num += weight * mean_r2 * y;
            den += weight * mean_r2 * mean_r2;
        }
        if den <= 0.0 {
            return pre; // fall back to coordinate variance
        }
        (num / den).max(1e-9)
    }
}

fn coordinate_variance(points: &[f64], n_dims: usize) -> f64 {
    let n = points.len() / n_dims;
    if n < 2 {
        return 1.0;
    }
    let mut mean = vec![0.0; n_dims];
    for r in 0..n {
        for d in 0..n_dims {
            mean[d] += points[r * n_dims + d];
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    let mut var = 0.0;
    for r in 0..n {
        for d in 0..n_dims {
            let dv = points[r * n_dims + d] - mean[d];
            var += dv * dv;
        }
    }
    var / (n as f64 * n_dims as f64)
}

/// Convenience: estimate σ² then build the Adapted-radius distribution.
pub fn fit_freq_dist(
    points: &[f64],
    n_dims: usize,
    kind: RadiusKind,
    rng: &mut Rng,
) -> FreqDist {
    let sigma2 = ScaleEstimator::default().estimate(points, n_dims, rng);
    FreqDist::new(kind, sigma2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gmm::GmmConfig;

    #[test]
    fn recovers_unit_cluster_scale() {
        let mut rng = Rng::new(1);
        let g = GmmConfig::paper_default(5, 8, 20_000).generate(&mut rng);
        let s2 = ScaleEstimator::default().estimate(&g.dataset.points, 8, &mut rng);
        // Unit clusters + mean spread: estimate should land within a small
        // multiplicative band of 1 (the fit sees cluster+mean variance mix).
        assert!(s2 > 0.3 && s2 < 12.0, "sigma2={s2}");
    }

    #[test]
    fn scales_with_data() {
        let mut rng = Rng::new(2);
        let mut g = GmmConfig::paper_default(4, 6, 10_000);
        g.cluster_std = 1.0;
        let d1 = g.generate(&mut rng);
        let scaled: Vec<f64> = d1.dataset.points.iter().map(|x| 3.0 * x).collect();
        let est = ScaleEstimator::default();
        let s_base = est.estimate(&d1.dataset.points, 6, &mut rng);
        let s_scaled = est.estimate(&scaled, 6, &mut rng);
        let ratio = s_scaled / s_base;
        assert!(ratio > 4.0 && ratio < 20.0, "ratio={ratio} (expect ≈9)");
    }

    #[test]
    fn empty_and_tiny_inputs_fall_back() {
        let mut rng = Rng::new(3);
        let est = ScaleEstimator::default();
        assert_eq!(est.estimate(&[], 4, &mut rng), est.sigma2_init);
        let one = vec![1.0, 2.0, 3.0];
        let s = est.estimate(&one, 3, &mut rng);
        assert!(s.is_finite() && s > 0.0);
    }

    #[test]
    fn fit_freq_dist_builds() {
        let mut rng = Rng::new(4);
        let g = GmmConfig::paper_default(3, 4, 2000).generate(&mut rng);
        let fd = fit_freq_dist(&g.dataset.points, 4, RadiusKind::AdaptedRadius, &mut rng);
        assert!(fd.sigma2 > 0.0);
        let w = fd.draw(100, 4, &mut rng);
        assert_eq!((w.rows, w.cols), (100, 4));
    }
}
