//! Batched atom kernels: the GEMM-backed solver-side hot paths.
//!
//! CLOMPR's per-iteration cost is dominated by atom evaluation — `K` atoms
//! `Aδ_{c_k} = e^{-i W c_k}`, their `K × K` Gram for the NNLS re-fits, and
//! the step-5 gradient of all `K` centroids at once. The scalar paths in
//! [`SketchOp`] evaluate those one centroid (one `matvec`/`matvec_t`) at a
//! time; this module rewrites them as batched products on the blocked,
//! threaded [`Mat`] primitives:
//!
//! - [`atoms_batch`] — one `C·Wᵀ` GEMM (`K × m` phases), then a vectorized
//!   `sin_cos` sweep.
//! - [`gram_and_corr`] — the NNLS normal equations via two `K × K` GEMMs
//!   (`Re·Reᵀ + Im·Imᵀ`) and two GEMVs instead of `K²` scalar `re_dot`s.
//! - [`mixture_sketch_batch`] — `αᵀ · atoms` over a pre-built atom block.
//! - [`step5_value_grads_batch`] — builds the `K × m` factor `Q` once, then
//!   a single `Q·W` GEMM (row-parallel) yields every centroid gradient.
//!
//! Every batched kernel preserves the scalar paths' accumulation order, so
//! outputs are bit-identical (modulo the sign of exact zeros) — the scalar
//! implementations are retained as correctness oracles and the parity is
//! enforced by property tests here and in `tests/properties.rs`.

use super::operator::SketchOp;
use crate::linalg::nnls::nnls_gram;
use crate::linalg::{CMat, CVec, Mat};
use crate::util::fastmath::{self, TrigBackend};
use crate::util::parallel;

/// Elementwise work below this size runs serially (thread spawn/join would
/// dwarf it); above it, sweeps split across the worker pool.
const PAR_SWEEP_THRESHOLD: usize = 8 * 1024;

/// All `K` atoms of a support at once: `atoms[k] = A δ_{c_k}` as a `K × m`
/// complex matrix. One `C·Wᵀ` GEMM, then a (parallel) `sin_cos` sweep —
/// the trig is the dominant cost at paper scale (`K·m` evaluations).
pub fn atoms_batch(op: &SketchOp, centroids: &Mat) -> CMat {
    let theta = centroids.matmul_bt(&op.w);
    let mut out = CMat::zeros(theta.rows, theta.cols);
    let len = theta.data.len();
    let trig = op.trig();
    let threads = if len >= PAR_SWEEP_THRESHOLD { parallel::default_threads() } else { 1 };
    let ranges = parallel::split_ranges(len, threads);
    if ranges.len() <= 1 {
        sin_cos_sweep(trig, &theta.data, &mut out.re.data, &mut out.im.data);
        return out;
    }
    std::thread::scope(|s| {
        let mut re_rest: &mut [f64] = &mut out.re.data;
        let mut im_rest: &mut [f64] = &mut out.im.data;
        for r in ranges {
            let (re_head, re_tail) = re_rest.split_at_mut(r.len());
            let (im_head, im_tail) = im_rest.split_at_mut(r.len());
            re_rest = re_tail;
            im_rest = im_tail;
            let th = &theta.data[r.start..r.end];
            s.spawn(move || sin_cos_sweep(trig, th, re_head, im_head));
        }
    });
    out
}

/// `re[i] = cos θ_i, im[i] = −sin θ_i` over a chunk, dispatched on the
/// operator's trig backend. Elementwise pure under both backends, so chunk
/// boundaries and thread splits cannot affect the result.
fn sin_cos_sweep(trig: TrigBackend, theta: &[f64], re: &mut [f64], im: &mut [f64]) {
    fastmath::atom_sweep(trig, theta, re, im);
}

/// Scalar oracle for [`atoms_batch`]: one `op.atom` matvec per centroid.
pub fn atoms_batch_scalar(op: &SketchOp, centroids: &Mat) -> CMat {
    let rows: Vec<CVec> = (0..centroids.rows).map(|k| op.atom(centroids.row(k))).collect();
    if rows.is_empty() {
        return CMat::zeros(0, op.m());
    }
    CMat::from_rows(&rows)
}

/// NNLS normal equations over an atom block: `G_ij = s² Re⟨u_i, u_j⟩` and
/// `h_j = s Re⟨u_j, ẑ⟩`, with `s` the common atom scale (1 for raw atoms,
/// `1/√m` for normalized ones). Two `K × K` GEMMs + two GEMVs.
pub fn gram_and_corr(atoms: &CMat, z_hat: &CVec, scale: f64) -> (Mat, Vec<f64>) {
    let s2 = scale * scale;
    let mut g = atoms.re.matmul_bt(&atoms.re);
    let g_im = atoms.im.matmul_bt(&atoms.im);
    for (a, b) in g.data.iter_mut().zip(&g_im.data) {
        *a = s2 * (*a + *b);
    }
    let h_re = atoms.re.matvec(&z_hat.re);
    let h_im = atoms.im.matvec(&z_hat.im);
    let h = h_re.iter().zip(&h_im).map(|(a, b)| scale * (a + b)).collect();
    (g, h)
}

/// NNLS weight fit over a pre-built atom block (CLOMPR steps 3/4):
/// `min_{β ≥ 0} ‖ẑ − Σ β_j u_j‖`, atoms normalized when `normalized`.
pub fn fit_weights(op: &SketchOp, z_hat: &CVec, atoms: &CMat, normalized: bool) -> Vec<f64> {
    let scale = if normalized { 1.0 / op.atom_norm() } else { 1.0 };
    let (g, h) = gram_and_corr(atoms, z_hat, scale);
    nnls_gram(&g, &h)
}

/// Scalar oracle for [`fit_weights`]: `K²` pairwise `re_dot`s on atom rows
/// (the pre-batch CLOMPR implementation, kept verbatim for parity tests).
pub fn fit_weights_scalar(
    op: &SketchOp,
    z_hat: &CVec,
    atoms: &CMat,
    normalized: bool,
) -> Vec<f64> {
    let kk = atoms.rows();
    let scale = if normalized { 1.0 / op.atom_norm() } else { 1.0 };
    let rows: Vec<CVec> = (0..kk).map(|k| atoms.row_cvec(k)).collect();
    let mut g = Mat::zeros(kk, kk);
    for i in 0..kk {
        for j in 0..=i {
            let v = scale * scale * rows[i].re_dot(&rows[j]);
            *g.at_mut(i, j) = v;
            *g.at_mut(j, i) = v;
        }
    }
    let h: Vec<f64> = rows.iter().map(|u| scale * u.re_dot(z_hat)).collect();
    nnls_gram(&g, &h)
}

/// Sketch of a weighted mixture over a pre-built atom block:
/// `Σ_k α_k u_k`. Same accumulation order (and zero-weight skip) as
/// `SketchOp::mixture_sketch`.
pub fn mixture_sketch_batch(atoms: &CMat, alpha: &[f64]) -> CVec {
    atoms.weighted_row_sum(alpha)
}

/// Step-5 cost and gradients, batched: cost `‖ẑ − Σ α_k u_k‖²`, `∂/∂α` via
/// two GEMVs, and `∂/∂C = −2 diag(α) · Q · W` via one row-parallel GEMM,
/// where `Q_{kj} = −(sinθ_{kj}·Re r_j + cosθ_{kj}·Im r_j)`.
pub fn step5_value_grads_batch(
    op: &SketchOp,
    z_hat: &CVec,
    centroids: &Mat,
    alpha: &[f64],
) -> (f64, Mat, Vec<f64>) {
    let atoms = atoms_batch(op, centroids);
    step5_value_grads_from_atoms(op, z_hat, &atoms, alpha)
}

/// [`step5_value_grads_batch`] over an already-materialized atom block.
pub fn step5_value_grads_from_atoms(
    op: &SketchOp,
    z_hat: &CVec,
    atoms: &CMat,
    alpha: &[f64],
) -> (f64, Mat, Vec<f64>) {
    let kk = atoms.rows();
    let m = op.m();
    assert_eq!(alpha.len(), kk);
    assert_eq!(z_hat.len(), m);
    let threads = if kk * m >= PAR_SWEEP_THRESHOLD { parallel::default_threads() } else { 1 };
    // Residual r = ẑ − Σ α_k u_k. Each component r_j accumulates over k in
    // row order (the scalar order), so splitting the *columns* across
    // threads is bit-neutral.
    let mut r = z_hat.clone();
    {
        let ranges = parallel::split_ranges(m, threads);
        if ranges.len() <= 1 {
            for k in 0..kk {
                atoms.axpy_row_into(k, -alpha[k], &mut r);
            }
        } else {
            let atoms_ref = &atoms;
            std::thread::scope(|s| {
                let mut re_rest: &mut [f64] = &mut r.re;
                let mut im_rest: &mut [f64] = &mut r.im;
                for rg in ranges {
                    let (re_head, re_tail) = re_rest.split_at_mut(rg.len());
                    let (im_head, im_tail) = im_rest.split_at_mut(rg.len());
                    re_rest = re_tail;
                    im_rest = im_tail;
                    let (start, end) = (rg.start, rg.end);
                    s.spawn(move || {
                        for k in 0..kk {
                            let coef = -alpha[k];
                            let (u_re, u_im) = atoms_ref.row(k);
                            let (u_re, u_im) = (&u_re[start..end], &u_im[start..end]);
                            for j in 0..re_head.len() {
                                re_head[j] += coef * u_re[j];
                                im_head[j] += coef * u_im[j];
                            }
                        }
                    });
                }
            });
        }
    }
    let cost = r.norm2_sq();
    // ∂g/∂α_k = −2 Re⟨u_k, r⟩ for all k: two GEMVs.
    let ga_re = atoms.re.matvec(&r.re);
    let ga_im = atoms.im.matvec(&r.im);
    let grad_a: Vec<f64> = ga_re.iter().zip(&ga_im).map(|(a, b)| -2.0 * (a + b)).collect();
    // Q_{kj} = −(sinθ·Re r + cosθ·Im r); note u.re = cosθ, u.im = −sinθ.
    // Elementwise in the flat K × m layout shared with the atom block, so
    // the sweep parallelizes over arbitrary chunks.
    let mut q = Mat::zeros(kk, m);
    parallel::parallel_chunks_mut(&mut q.data, threads, |off, chunk| {
        for (idx, qv) in chunk.iter_mut().enumerate() {
            let e = off + idx;
            let j = e % m;
            let (co, s) = (atoms.re.data[e], -atoms.im.data[e]);
            *qv = -(s * r.re[j] + co * r.im[j]);
        }
    });
    // All K centroid gradients in one GEMM against the cached transpose:
    // ∇_{c_k} g = −2 α_k (Q·W)_k.
    let qw = q.matmul_bt(op.w_t());
    let mut grad_c = Mat::zeros(kk, op.n_dims());
    for k in 0..kk {
        let src = qw.row(k);
        let dst = grad_c.row_mut(k);
        for d in 0..src.len() {
            dst[d] = -2.0 * alpha[k] * src[d];
        }
    }
    (cost, grad_c, grad_a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::frequencies::FreqDist;
    use crate::testing::{self, gen, Config};
    use crate::util::rng::Rng;

    fn op(m: usize, n: usize, seed: u64) -> SketchOp {
        let mut rng = Rng::new(seed);
        SketchOp::new(FreqDist::adapted(1.0).draw(m, n, &mut rng))
    }

    fn rand_support(rng: &mut Rng, k: usize, n: usize) -> (Mat, Vec<f64>) {
        let c = Mat::from_vec(k, n, gen::mat_normal(rng, k, n));
        let a: Vec<f64> = (0..k).map(|_| rng.uniform()).collect();
        (c, a)
    }

    #[test]
    fn prop_atoms_batch_bit_matches_scalar() {
        testing::check("atoms_batch == scalar", Config::default().cases(24).max_size(40), |rng, size| {
            let n = 1 + rng.below(8);
            let k = 1 + rng.below(1 + size / 4);
            let o = op(8 + rng.below(size), n, rng.next_u64());
            let (c, _) = rand_support(rng, k, n);
            let fast = atoms_batch(&o, &c);
            let slow = atoms_batch_scalar(&o, &c);
            testing::all_close(&fast.re.data, &slow.re.data, 0.0)?;
            testing::all_close(&fast.im.data, &slow.im.data, 0.0)
        });
    }

    #[test]
    fn prop_gram_and_corr_bit_matches_scalar() {
        testing::check("gram/corr == scalar", Config::default().cases(20).max_size(40), |rng, size| {
            let n = 1 + rng.below(6);
            let k = 1 + rng.below(8);
            let m = 8 + rng.below(size);
            let o = op(m, n, rng.next_u64());
            let (c, _) = rand_support(rng, k, n);
            let z = CVec::from_parts(gen::vec_normal(rng, m), gen::vec_normal(rng, m));
            let atoms = atoms_batch(&o, &c);
            for normalized in [false, true] {
                let scale = if normalized { 1.0 / o.atom_norm() } else { 1.0 };
                let (g, h) = gram_and_corr(&atoms, &z, scale);
                // scalar oracle
                for i in 0..k {
                    for j in 0..k {
                        let v = scale * scale * atoms.row_cvec(i).re_dot(&atoms.row_cvec(j));
                        testing::close(g.at(i, j), v, 0.0)?;
                    }
                    let hv = scale * atoms.row_cvec(i).re_dot(&z);
                    testing::close(h[i], hv, 0.0)?;
                }
                // weights agree too
                let fast = fit_weights(&o, &z, &atoms, normalized);
                let slow = fit_weights_scalar(&o, &z, &atoms, normalized);
                testing::all_close(&fast, &slow, 0.0)?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_mixture_batch_bit_matches_scalar() {
        testing::check("mixture batch == scalar", Config::default().cases(20).max_size(30), |rng, size| {
            let n = 1 + rng.below(5);
            let k = 1 + rng.below(8);
            let o = op(8 + rng.below(size), n, rng.next_u64());
            let (c, mut a) = rand_support(rng, k, n);
            a[rng.below(k)] = 0.0; // exercise the zero-skip path
            let atoms = atoms_batch(&o, &c);
            let fast = mixture_sketch_batch(&atoms, &a);
            let slow = o.mixture_sketch(&c, &a);
            testing::all_close(&fast.re, &slow.re, 0.0)?;
            testing::all_close(&fast.im, &slow.im, 0.0)
        });
    }

    #[test]
    fn prop_step5_batch_matches_scalar() {
        testing::check("step5 batch == scalar", Config::default().cases(16).max_size(40), |rng, size| {
            let n = 1 + rng.below(6);
            let k = 1 + rng.below(8);
            let m = 8 + rng.below(size);
            let o = op(m, n, rng.next_u64());
            let (c, a) = rand_support(rng, k, n);
            let z = CVec::from_parts(gen::vec_normal(rng, m), gen::vec_normal(rng, m));
            let (cost_b, gc_b, ga_b) = step5_value_grads_batch(&o, &z, &c, &a);
            let (cost_s, gc_s, ga_s) = o.step5_value_grads(&z, &c, &a);
            testing::close(cost_b, cost_s, 0.0)?;
            testing::all_close(&ga_b, &ga_s, 0.0)?;
            // Centroid gradients: identical accumulation order except the
            // scalar matvec_t skips exact-zero q entries (sign-of-zero only);
            // compare at 1e-12.
            testing::all_close(&gc_b.data, &gc_s.data, 1e-12)
        });
    }

    #[test]
    fn paper_scale_parity_exercises_parallel_sweeps() {
        // K·m = 10240 ≥ PAR_SWEEP_THRESHOLD: the threaded sin_cos, residual
        // and Q sweeps run here, and must still bit-match the scalar paths.
        let o = op(1024, 10, 99);
        let mut rng = Rng::new(100);
        let (c, a) = rand_support(&mut rng, 10, 10);
        let z =
            CVec::from_parts(gen::vec_normal(&mut rng, 1024), gen::vec_normal(&mut rng, 1024));
        let fast = atoms_batch(&o, &c);
        let slow = atoms_batch_scalar(&o, &c);
        testing::all_close(&fast.re.data, &slow.re.data, 0.0).unwrap();
        testing::all_close(&fast.im.data, &slow.im.data, 0.0).unwrap();
        let (cost_b, gc_b, ga_b) = step5_value_grads_batch(&o, &z, &c, &a);
        let (cost_s, gc_s, ga_s) = o.step5_value_grads(&z, &c, &a);
        testing::close(cost_b, cost_s, 0.0).unwrap();
        testing::all_close(&ga_b, &ga_s, 0.0).unwrap();
        testing::all_close(&gc_b.data, &gc_s.data, 1e-12).unwrap();
    }

    #[test]
    fn atoms_batch_bit_matches_scalar_under_fast_trig() {
        // The fast kernel is elementwise pure, so the batched (threaded,
        // arbitrary-split) sweep must still bit-match the per-atom oracle.
        // K·m = 11200 ≥ PAR_SWEEP_THRESHOLD exercises the parallel path.
        let mut rng = Rng::new(55);
        let w = FreqDist::adapted(1.0).draw(700, 6, &mut rng);
        let o = SketchOp::with_trig(w, TrigBackend::Fast);
        let (c, _) = rand_support(&mut rng, 16, 6);
        let fast = atoms_batch(&o, &c);
        let slow = atoms_batch_scalar(&o, &c);
        testing::all_close(&fast.re.data, &slow.re.data, 0.0).unwrap();
        testing::all_close(&fast.im.data, &slow.im.data, 0.0).unwrap();
    }

    #[test]
    fn empty_support() {
        let o = op(16, 3, 1);
        let c = Mat::zeros(0, 3);
        let atoms = atoms_batch(&o, &c);
        assert_eq!(atoms.rows(), 0);
        assert_eq!(fit_weights(&o, &CVec::zeros(16), &atoms, false), Vec::<f64>::new());
        let z = mixture_sketch_batch(&atoms, &[]);
        assert_eq!(z.len(), 16);
        assert!(z.norm2_sq() == 0.0);
    }
}
