//! `ckm` — the compressive K-means coordinator CLI.
//!
//! Subcommands:
//!   run     end-to-end (stream → sketch → CLOMPR → report) via the facade
//!   sketch  sketch a dataset file into a durable sketch artifact
//!   merge   merge shard artifacts (exact; operator-checked)
//!   solve   recover centroids from a sketch artifact (any K, repeatedly)
//!   window  epoch replay through the windowed sketch store (drift demo)
//!   convert flip a checkpoint between the JSON and binary (CKMC) codecs
//!   exp     regenerate a paper figure: fig1 | fig2 | fig3 | fig4 | ablate
//!           (plus the quantize and decoders ablations)
//!   gen     generate a synthetic dataset file
//!   info    show version, artifact manifest, decoder registry, backends

use ckm::api::{Ckm, CkmBuilder, QuantizationMode, SketchArtifact};
use ckm::baselines::{kmeans, KmInit, KmOptions};
use ckm::ckm::{InitStrategy, Solution};
use ckm::coordinator::Backend;
use ckm::data::dataset::{Dataset, PointSource, SliceSource};
use ckm::data::gmm::GmmConfig;
use ckm::experiments as exp;
use ckm::metrics::sse;
use ckm::sketch::RadiusKind;
use ckm::util::cli::Args;
use ckm::util::logging::Stopwatch;
use ckm::util::rng::Rng;

fn main() {
    ckm::util::logging::init();
    let args = Args::from_env();
    let result = match args.command.as_deref() {
        Some("run") => cmd_run(&args),
        Some("exp") => cmd_exp(&args),
        Some("gen") => cmd_gen(&args),
        Some("sketch") => cmd_sketch(&args),
        Some("merge") => cmd_merge(&args),
        Some("solve") => cmd_solve(&args),
        Some("window") => cmd_window(&args),
        Some("convert") => cmd_convert(&args),
        Some("client") => cmd_client(&args),
        Some("bench") => cmd_bench(&args),
        Some("info") => cmd_info(&args),
        Some(other) => {
            eprintln!("unknown command '{other}'");
            usage();
            std::process::exit(2);
        }
        None => {
            usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    println!(
        "ckm {} — compressive K-means (Keriven et al. 2016)\n\
         \n\
         usage: ckm <command> [options]\n\
         \n\
         commands:\n\
           run     --k 10 --m 1000 --n 10 --npoints 300000 [--file data.bin]\n\
                   [--backend native|pjrt] [--trig exact|fast] [--workers 4]\n\
                   [--replicates 1] [--strategy range|sample|k++] [--sigma2 X]\n\
                   [--decoder clompr|hierarchical|sketch-shift]\n\
                   [--seed S] [--quantize 1bit|..|16bit]\n\
                   [--save-sketch sketch.json] [--compare-kmeans]\n\
           sketch  --file data.bin --m 1000 --out sketch.json [--sigma2 X] [--seed S]\n\
                   [--trig exact|fast] [--quantize 1bit|..|16bit]\n\
                   [--shard I  (one id per site)]\n\
           merge   --out merged.json shard1.json shard2.json ...\n\
           solve   --sketch sketch.json --k 10 [--replicates R] [--seed S]\n\
                   [--decoder clompr|hierarchical|sketch-shift]\n\
                   [--trig exact|fast  (must match the sketch's provenance)]\n\
                   [--out solution.json]\n\
           window  --epochs 6 --epoch-rows 20000 --k 5 [--retain E] [--window W]\n\
                   [--decay 0.2] [--drift 4.0] [--quantize 1bit|..|16bit]\n\
                   [--trig exact|fast] [--save-store store.json]\n\
                   (epoch replay through the store)\n\
           convert <input> <output>  flip a sketch / store / store-set\n\
                   checkpoint between JSON and the binary CKMC container\n\
                   (direction sniffed from the input's codec)\n\
           client  ingest|solve|rotate|status|checkpoint|shutdown\n\
                   --connect tcp:HOST:PORT|unix:PATH [--producer NAME] ...\n\
                   (talk to a ckmd sketch daemon; same verbs as ckm-client)\n\
           exp     fig1|fig2|fig3|fig4|ablate|quantize|decoders\n\
                   [--runs R] [--full] [--persist]\n\
           bench   diff <baseline.json> <candidate.json> [--threshold 1.5]\n\
                   (fails on tracked-op ns_per_iter regressions beyond the threshold)\n\
           gen     --out data.bin --k 10 --n 10 --npoints 100000 [--seed S]\n\
           info    (version, threads, trig SIMD dispatch path, artifacts)\n\
         \n\
         env: CKM_THREADS=N  worker threads (1..=64)\n\
              CKM_SIMD=scalar|lanes|avx2|avx512|neon|auto  trig dispatch override\n\
              (--trig exact|fast is the provenance knob; CKM_SIMD only picks\n\
               among bit-identical fast-path kernels)",
        ckm::version()
    );
}

/// `ckm client <verb>`: the same verbs as the `ckm-client` binary.
fn cmd_client(args: &Args) -> anyhow::Result<()> {
    match args.positionals().first() {
        Some(verb) => ckm::service::cli::run_client(verb, args),
        None => {
            ckm::service::cli::client_usage();
            Ok(())
        }
    }
}

/// Shared builder plumbing for the pipeline-shaped commands.
fn builder_from_args(args: &Args) -> anyhow::Result<CkmBuilder> {
    let mut b = Ckm::builder()
        .frequencies(args.usize_or("m", 1000))
        .backend(Backend::parse(&args.str_or("backend", "native"))?)
        .replicates(args.usize_or("replicates", 1))
        .strategy(InitStrategy::parse(&args.str_or("strategy", "range"))?)
        .radius(RadiusKind::parse(&args.str_or("radius", "adapted"))?)
        .trig(ckm::util::fastmath::TrigBackend::parse(&args.str_or("trig", "exact"))?)
        .seed(args.u64_or("seed", 0))
        .workers(args.usize_or("workers", 4))
        .chunk_rows(args.usize_or("chunk-rows", 4096))
        .queue_depth(args.usize_or("queue-depth", 8))
        .shard(args.u64_or("shard", 0));
    if let Some(d) = args.opt("decoder") {
        b = b.decoder(ckm::decoder::DecoderSpec::parse(d)?);
    }
    if let Some(s2) = args.opt("sigma2") {
        b = b.sigma2(s2.parse()?);
    }
    if let Some(q) = args.opt("quantize") {
        if !matches!(q, "none" | "dense") {
            b = b.quantization(QuantizationMode::parse(q)?);
        }
    }
    Ok(b)
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let k = args.usize_or("k", 10);
    let n_dims = args.usize_or("n", 10);
    let n_points = args.usize_or("npoints", 300_000);
    let seed = args.u64_or("seed", 0);
    let ckm = builder_from_args(args)?.build()?;
    let file = args.opt("file").map(|s| s.to_string());
    let save_sketch = args.opt("save-sketch").map(|s| s.to_string());
    let compare = args.flag("compare-kmeans");
    args.finish()?;

    let t_total = Stopwatch::start();
    let (artifact, stats, material): (_, _, Option<Dataset>) = match file {
        Some(path) => {
            let ds = Dataset::load(std::path::Path::new(&path))?;
            println!("loaded {}: N={} n={}", path, ds.n_points(), ds.n_dims);
            let sample_len = ds.points.len().min(5000 * ds.n_dims);
            let sample = ds.points[..sample_len].to_vec();
            let mut src = SliceSource::new(&ds.points, ds.n_dims);
            let (artifact, stats) = ckm.sketch_from(&mut src, Some(&sample))?;
            (artifact, stats, Some(ds))
        }
        None => {
            println!("synthetic GMM: K={k} n={n_dims} N={n_points}");
            let data_cfg = GmmConfig::paper_default(k, n_dims, n_points);
            // σ² sample from a sibling stream when not given.
            let mut sample = vec![0.0; 5000.min(n_points) * n_dims];
            let got = data_cfg.stream(seed).next_chunk(&mut sample);
            sample.truncate(got * n_dims);
            let mut src = data_cfg.stream(seed);
            let (artifact, stats) = ckm.sketch_from(&mut src, Some(&sample))?;
            (artifact, stats, None)
        }
    };

    println!(
        "sketched N={} in {:.2}s ({:.2} Mpts/s, backend={}, {} workers, {:.0}x compression, \
         {} B of partials shipped{})",
        artifact.count,
        stats.wall_seconds,
        stats.throughput() / 1e6,
        stats.backend,
        stats.rows_per_worker.len(),
        artifact.compression_ratio(),
        stats.shipped_bytes,
        match &artifact.quant {
            Some(q) => format!(", {} quantized", q.mode.name()),
            None => String::new(),
        },
    );
    if let Some(path) = save_sketch {
        artifact.to_file(&path)?;
        println!("sketch artifact written to {path}");
    }

    let t_solve = Stopwatch::start();
    let report = ckm.solve_detailed(&artifact, k, None)?;
    println!(
        "solved in {:.2}s: cost={:.4e}  sigma2={:.3}  replicate costs={:?}",
        t_solve.seconds(),
        report.solution.cost,
        artifact.op.sigma2,
        report.replicate_costs
    );
    print_solution(&report.solution);
    if let Some(ds) = material {
        let s = sse(&ds.points, ds.n_dims, &report.solution.centroids);
        println!("SSE/N = {:.4}", s / ds.n_points() as f64);
        if compare {
            let sw = Stopwatch::start();
            let km = kmeans(
                &ds.points,
                ds.n_dims,
                k,
                &KmOptions {
                    init: KmInit::Range,
                    replicates: 5,
                    seed: seed + 1,
                    ..Default::default()
                },
            );
            println!(
                "kmeans x5: SSE/N = {:.4} in {:.2}s  (rel SSE = {:.3})",
                km.sse / ds.n_points() as f64,
                sw.seconds(),
                s / km.sse
            );
        }
    }
    println!("total {:.2}s", t_total.seconds());
    Ok(())
}

fn print_solution(sol: &Solution) {
    println!("weights: {:?}", sol.normalized_weights());
    for kk in 0..sol.centroids.rows.min(5) {
        println!("  c[{kk}] = {:?}", sol.centroids.row(kk));
    }
    if sol.centroids.rows > 5 {
        println!("  ... ({} total)", sol.centroids.rows);
    }
}

fn cmd_exp(args: &Args) -> anyhow::Result<()> {
    let which = args
        .positionals()
        .first()
        .cloned()
        .ok_or_else(|| {
            anyhow::anyhow!("exp needs a figure: fig1|fig2|fig3|fig4|ablate|quantize|decoders")
        })?;
    let persist = args.flag("persist");
    let full = args.flag("full");
    let runs = args.opt("runs").map(|r| r.parse::<usize>()).transpose()?;
    let seed = args.u64_or("seed", 42);

    match which.as_str() {
        "fig1" => {
            let mut cfg = exp::fig1::Fig1Config { seed, ..Default::default() };
            if full {
                cfg.n_points = 300_000;
                cfg.runs = 100;
                cfg.digit_images = 3000;
            }
            if let Some(r) = runs {
                cfg.runs = r;
            }
            args.finish()?;
            exp::fig1::run(&cfg).emit("fig1", persist);
        }
        "fig2" => {
            let mut cfg = exp::fig2::Fig2Config { seed, ..Default::default() };
            if full {
                cfg.n_points = 300_000;
                cfg.runs = 10;
                cfg.ks = vec![2, 5, 10, 15, 20, 30];
                cfg.ns = vec![2, 4, 6, 10, 14, 20];
                cfg.ratios = vec![0.5, 1.0, 2.0, 3.0, 5.0, 7.0, 10.0];
            }
            if let Some(r) = runs {
                cfg.runs = r;
            }
            args.finish()?;
            exp::fig2::run(&cfg).emit("fig2", persist);
        }
        "fig3" => {
            let mut cfg = exp::fig3::Fig3Config { seed, ..Default::default() };
            if full {
                cfg.sizes = vec![2000, 6000, 20_000];
                cfg.runs = 20;
            }
            if let Some(r) = runs {
                cfg.runs = r;
            }
            args.finish()?;
            exp::fig3::run(&cfg).emit("fig3", persist);
        }
        "fig4" => {
            let mut cfg = exp::fig4::Fig4Config { seed, ..Default::default() };
            if full {
                cfg.n_sweep = vec![10_000, 30_000, 100_000, 300_000, 1_000_000, 10_000_000];
                cfg.ms = vec![250, 1000, 4000];
            }
            args.finish()?;
            exp::fig4::run(&cfg).emit("fig4", persist);
        }
        "ablate" => {
            let mut cfg = exp::ablate::AblateConfig { seed, ..Default::default() };
            if let Some(r) = runs {
                cfg.runs = r;
            }
            if full {
                cfg.n_points = 100_000;
                cfg.runs = 10;
            }
            args.finish()?;
            for t in exp::ablate::run(&cfg) {
                t.emit("ablate", persist);
            }
        }
        "quantize" => {
            let mut cfg = exp::quantize::QuantizeConfig { seed, ..Default::default() };
            if let Some(r) = runs {
                cfg.runs = r;
            }
            if full {
                cfg.n_points = 100_000;
                cfg.runs = 10;
            }
            args.finish()?;
            exp::quantize::run(&cfg).emit("quantize", persist);
        }
        "decoders" => {
            let mut cfg = exp::decoders::DecodersConfig { seed, ..Default::default() };
            if let Some(r) = runs {
                cfg.runs = r;
            }
            if full {
                cfg.n_points = 100_000;
                cfg.runs = 10;
            }
            args.finish()?;
            exp::decoders::run(&cfg).emit("decoders", persist);
        }
        other => anyhow::bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

fn cmd_gen(args: &Args) -> anyhow::Result<()> {
    let out = args
        .opt("out")
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow::anyhow!("gen needs --out"))?;
    let k = args.usize_or("k", 10);
    let n_dims = args.usize_or("n", 10);
    let n_points = args.usize_or("npoints", 100_000);
    let seed = args.u64_or("seed", 0);
    args.finish()?;
    let mut rng = Rng::new(seed);
    let g = GmmConfig::paper_default(k, n_dims, n_points).generate(&mut rng);
    g.dataset.save(std::path::Path::new(&out))?;
    println!("wrote {out}: N={n_points} n={n_dims} K={k}");
    Ok(())
}

fn cmd_sketch(args: &Args) -> anyhow::Result<()> {
    let file = args
        .opt("file")
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow::anyhow!("sketch needs --file"))?;
    let out = args.str_or("out", "sketch.json");
    let ckm = builder_from_args(args)?.build()?;
    args.finish()?;
    let ds = Dataset::load(std::path::Path::new(&file))?;
    let artifact = ckm.sketch_slice(&ds.points, ds.n_dims)?;
    artifact.to_file(&out)?;
    println!(
        "sketched {} points into {out} ({} complex moments, {:.0}x compression); \
         merge shards with `ckm merge`, recover centroids with `ckm solve`",
        artifact.count,
        artifact.op.m,
        artifact.compression_ratio()
    );
    Ok(())
}

fn cmd_merge(args: &Args) -> anyhow::Result<()> {
    let out = args
        .opt("out")
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow::anyhow!("merge needs --out"))?;
    args.finish()?;
    let paths = args.positionals();
    anyhow::ensure!(paths.len() >= 2, "merge needs at least two shard artifacts");
    let mut merged: Option<SketchArtifact> = None;
    for p in paths {
        let shard = SketchArtifact::from_file(p)?;
        println!("  {p}: {} points ({})", shard.count, shard.op.describe());
        merged = Some(match merged {
            None => shard,
            Some(acc) => acc.merge(&shard)?,
        });
    }
    let merged = merged.expect("at least two shards");
    merged.to_file(&out)?;
    println!("merged {} shards -> {out}: {} points total", paths.len(), merged.count);
    Ok(())
}

fn cmd_solve(args: &Args) -> anyhow::Result<()> {
    let sketch_path = args
        .opt("sketch")
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow::anyhow!("solve needs --sketch (see `ckm sketch`)"))?;
    let k = args.usize_or("k", 10);
    let out = args.opt("out").map(|s| s.to_string());
    let ckm = builder_from_args(args)?.build()?;
    args.finish()?;

    let artifact = SketchArtifact::from_file(&sketch_path)?;
    println!(
        "loaded {sketch_path}: {} points, operator {}",
        artifact.count,
        artifact.op.describe()
    );
    let sw = Stopwatch::start();
    let report = ckm.solve_detailed(&artifact, k, None)?;
    println!(
        "solved K={k} in {:.2}s (cost {:.4e}, replicate costs {:?})",
        sw.seconds(),
        report.solution.cost,
        report.replicate_costs
    );
    print_solution(&report.solution);
    if let Some(path) = out {
        report.solution.to_file(&path)?;
        println!("solution written to {path}");
    }
    Ok(())
}

/// Epoch replay through the windowed sketch store: a synthetic (optionally
/// drifting) GMM stream is ingested one epoch at a time through a
/// [`ckm::store::SketchServer`], then window / decayed snapshots are
/// solved and the window(all) snapshot is verified against an independent
/// re-sketch of the surviving rows.
fn cmd_window(args: &Args) -> anyhow::Result<()> {
    use ckm::sketch::quantize::QuantizedAccumulator;
    use std::collections::VecDeque;

    let k = args.usize_or("k", 5);
    let n_dims = args.usize_or("n", 6);
    let epochs = args.usize_or("epochs", 6);
    let per_epoch = args.usize_or("epoch-rows", 20_000);
    let retain = args.usize_or("retain", epochs);
    let width = args.usize_or("window", retain);
    let drift = args.f64_or("drift", 0.0);
    let decay = args.opt("decay").map(|s| s.parse::<f64>()).transpose()?;
    let seed = args.u64_or("seed", 0);
    let save_store = args.opt("save-store").map(|s| s.to_string());

    let mut builder = builder_from_args(args)?.window(retain).decay_opt(decay);
    let data_cfg = GmmConfig::paper_default(k, n_dims, per_epoch);
    if args.opt("sigma2").is_none() {
        // A store outlives any one dataset, so σ² must be fixed up front:
        // estimate it once from a sample of the epoch-0 distribution.
        let mut sample = vec![0.0; 5000.min(per_epoch) * n_dims];
        let got = data_cfg.stream(seed).next_chunk(&mut sample);
        sample.truncate(got * n_dims);
        let mut rng = Rng::new(seed);
        let est = ckm::sketch::scale::ScaleEstimator::default().estimate(&sample, n_dims, &mut rng);
        builder = builder.sigma2(est);
    }
    let ckm = builder.build()?;
    args.finish()?;

    let server = ckm.server(n_dims)?;
    let mut rng = Rng::new(seed ^ 0xD217);
    let mut means = data_cfg.draw_means(&mut rng);
    let mut retained: VecDeque<Vec<f64>> = VecDeque::new();
    let sw = Stopwatch::start();
    for e in 0..epochs {
        if e > 0 {
            for mu in means.iter_mut() {
                mu[0] += drift;
            }
            let evicted = server.rotate();
            for id in &evicted {
                retained.pop_front();
                println!("  evicted epoch {id} (bucket drop: surviving windows stay exact)");
            }
        }
        let g = data_cfg.generate_with_means(&means, &mut rng);
        let mut sess = server.session();
        sess.push(&g.dataset.points);
        sess.finish();
        retained.push_back(g.dataset.points);
        println!(
            "epoch {e}: ingested {per_epoch} rows (mean drift offset {:+.1})",
            e as f64 * drift
        );
    }
    let stats = server.stats();
    println!(
        "replayed {} rows into {} surviving epochs in {:.2}s ({:.2} Mrows/s)",
        stats.rows_ingested,
        stats.epochs,
        sw.seconds(),
        stats.rows_ingested as f64 / sw.seconds().max(1e-12) / 1e6
    );

    // Verify: the window over every surviving epoch IS the sketch of the
    // surviving rows — bit-for-bit in quantized mode, fp-addition-order in
    // dense mode.
    let win = server.window_all();
    match ckm.config().quantization {
        Some(mode) => {
            let (spec, dither, epoch_stats) = server
                .with_store(|s| (s.spec().clone(), s.dither_seed(), s.epoch_stats()));
            let op = spec.materialize()?;
            let mut acc = QuantizedAccumulator::new(spec.m, n_dims, mode, dither);
            for (ep, rows) in epoch_stats.iter().zip(&retained) {
                acc.update(&op, rows, ep.start_row);
            }
            let direct = ckm::api::SketchArtifact::from_quantized(spec, &acc);
            let exact = win == direct;
            println!("window(all) vs direct re-sketch: bit-identical = {exact}");
            anyhow::ensure!(exact, "quantized window must replay bit-for-bit");
        }
        None => {
            let all_rows: Vec<f64> = retained.iter().flatten().copied().collect();
            let direct = ckm.sketch_slice(&all_rows, n_dims)?;
            anyhow::ensure!(win.count == direct.count, "window row count drifted");
            let max_diff = win.z().max_abs_diff(&direct.z());
            println!("window(all) vs single-pass re-sketch: max |Δz| = {max_diff:.3e}");
            anyhow::ensure!(max_diff < 1e-9, "dense window must match the re-sketch");
        }
    }

    let recovery =
        |sol: &Solution| -> f64 { ckm::metrics::mean_min_centroid_dist(&means, &sol.centroids) };

    let sw = Stopwatch::start();
    let sol = server.solve_window(width, k)?;
    println!(
        "\nwindow({width}) solve: cost {:.4e} in {:.2}s, mean dist to current means {:.3}",
        sol.cost,
        sw.seconds(),
        recovery(&sol)
    );
    print_solution(&sol);
    if let Some(lambda) = decay {
        let sw = Stopwatch::start();
        let dsol = server.solve_decayed(lambda, k)?;
        println!(
            "decayed(λ={lambda}) solve: cost {:.4e} in {:.2}s, mean dist to current means {:.3}",
            dsol.cost,
            sw.seconds(),
            recovery(&dsol)
        );
    }
    let sw = Stopwatch::start();
    let _ = server.solve_window(width, k)?;
    println!(
        "repeat window({width}) solve: {:.4}s ({} cache hits)",
        sw.seconds(),
        server.stats().cache_hits
    );
    if let Some(path) = save_store {
        server.save(&path)?;
        println!("store checkpointed to {path} (resume with SketchStore::from_file)");
    }
    Ok(())
}

/// `ckm convert <in> <out>`: flip a checkpoint file between the JSON and
/// binary (CKMC) codecs. The target codec is the opposite of the input's
/// (sniffed by magic); the document kind — sketch artifact, store, or
/// store set — is preserved, and the input is fully re-validated before
/// the output is written.
fn cmd_convert(args: &Args) -> anyhow::Result<()> {
    args.finish()?;
    let pos = args.positionals();
    anyhow::ensure!(pos.len() == 2, "usage: ckm convert <input> <output>");
    let report = ckm::store::convert_file(&pos[0], &pos[1])?;
    println!(
        "converted {} ({} -> {}): {} -> {} bytes ({:.2}x)",
        report.doc.name(),
        report.from.name(),
        report.to.name(),
        report.bytes_in,
        report.bytes_out,
        report.bytes_in as f64 / report.bytes_out.max(1) as f64
    );
    Ok(())
}

/// Compare two BENCH.json reports and fail on ns_per_iter regressions —
/// the CI bench-smoke gate. Baseline records without a real timing (the
/// committed bootstrap state) are informational and never gate.
fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    let threshold = args.f64_or("threshold", 1.5);
    args.finish()?;
    let pos = args.positionals();
    anyhow::ensure!(
        pos.first().map(String::as_str) == Some("diff") && pos.len() == 3,
        "usage: ckm bench diff <baseline.json> <candidate.json> [--threshold 1.5]"
    );
    let load = |p: &str| -> anyhow::Result<ckm::util::json::Json> {
        let text = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("cannot read {p}: {e}"))?;
        Ok(ckm::util::json::Json::parse(&text)?)
    };
    let baseline = load(&pos[1])?;
    let candidate = load(&pos[2])?;
    let diff = ckm::bench::diff_reports(&baseline, &candidate, threshold)
        .map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "bench diff ({}x gate): {} compared, {} skipped (bootstrap/missing), {} new",
        threshold,
        diff.compared(),
        diff.skipped,
        diff.new_ops.len()
    );
    for d in &diff.improvements {
        println!("  faster   {}", d.describe());
    }
    for d in &diff.steady {
        println!("  steady   {}", d.describe());
    }
    for op in &diff.new_ops {
        println!("  new      {op} (will gate once a baseline is committed)");
    }
    if diff.regressions.is_empty() {
        println!("OK: no tracked op regressed beyond {threshold}x");
        Ok(())
    } else {
        for d in &diff.regressions {
            eprintln!("  REGRESSION {}", d.describe());
        }
        anyhow::bail!("{} tracked op(s) regressed beyond {threshold}x", diff.regressions.len())
    }
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    args.finish()?;
    println!("ckm {}", ckm::version());
    println!("threads: {} (CKM_THREADS to override)", ckm::util::parallel::default_threads());
    let avail: Vec<&str> =
        ckm::util::fastmath::available_kernels().iter().map(|k| k.name()).collect();
    println!(
        "trig dispatch: {} (available: {}; CKM_SIMD to override)",
        ckm::util::fastmath::active_path(),
        avail.join(" ")
    );
    println!("cpu features: {}", ckm::util::fastmath::detected_cpu_features());
    println!(
        "decoders: {} (select with --decoder)",
        ckm::decoder::DecoderSpec::available_names().join(" ")
    );
    let dir = ckm::runtime::PjrtRuntime::default_dir();
    println!("artifacts dir: {dir:?}");
    match ckm::runtime::Manifest::load(&dir) {
        Ok(man) => {
            println!(
                "manifest: chunk_b={} n_pad={} k_pad={} ({} artifacts)",
                man.chunk_b,
                man.n_pad,
                man.k_pad,
                man.artifacts.len()
            );
            for a in man.artifacts.values() {
                println!("  {:30} entry={:7} m={:5} iters={}", a.name, a.entry, a.m, a.iters);
            }
        }
        Err(e) => println!("no artifacts ({e}); native backend only"),
    }
    Ok(())
}
