#!/usr/bin/env python3
"""Regenerate the golden CKMC container fixtures under rust/tests/fixtures/.

These files pin the *container envelope* byte layout (magic, version,
section table, FNV-1a checksums, footer + trailer, and the append-without-
rewrite tail format) independently of the Rust implementation, so an
accidental format change breaks `rust/tests/container_fixtures.rs` loudly.

The payload bytes are deterministic synthetic patterns, not real sketch
artifacts: document-level decoding re-derives and verifies the sketching
operator's checksum, which only the Rust library can produce. Document
roundtrips are covered by unit tests in rust/src/store/checkpoint.rs; the
fixtures cover the layer below.

Must be byte-for-byte in sync with rust/src/util/container.rs and the
expectations hard-coded in rust/tests/container_fixtures.rs.
"""

import os
import struct

CONTAINER_MAGIC = b"CKMC"
FOOTER_MAGIC = b"CKMF"
VERSION = 1

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MASK64 = (1 << 64) - 1


def fnv1a(data: bytes) -> int:
    h = FNV_OFFSET
    for b in data:
        h = ((h ^ b) * FNV_PRIME) & MASK64
    return h


def pattern(n: int, mul: int, mod: int) -> bytes:
    return bytes((mul * i) % mod for i in range(n))


def footer_body(state: bytes, entries) -> bytes:
    out = struct.pack("<Q", len(state)) + state
    out += struct.pack("<I", len(entries))
    for kind, tag, offset, length, checksum in entries:
        out += struct.pack("<BQQQQ", kind, tag, offset, length, checksum)
    return out


def container(state: bytes, sections) -> bytes:
    """sections: list of (kind, tag, payload)."""
    body = CONTAINER_MAGIC + struct.pack("<I", VERSION)
    entries = []
    for kind, tag, payload in sections:
        entries.append((kind, tag, len(body), len(payload), fnv1a(payload)))
        body += payload
    footer = footer_body(state, entries)
    body += footer
    body += struct.pack("<QQ", len(footer), fnv1a(footer))
    body += FOOTER_MAGIC
    return body


def parse_entries(blob: bytes):
    """Minimal reader: footer entries + the footer start offset."""
    footer_len, footer_fnv = struct.unpack("<QQ", blob[-20:-4])
    assert blob[-4:] == FOOTER_MAGIC
    footer_start = len(blob) - 20 - footer_len
    footer = blob[footer_start : len(blob) - 20]
    assert fnv1a(footer) == footer_fnv
    state_len = struct.unpack("<Q", footer[:8])[0]
    pos = 8 + state_len
    n = struct.unpack("<I", footer[pos : pos + 4])[0]
    pos += 4
    entries = []
    for _ in range(n):
        entries.append(struct.unpack("<BQQQQ", footer[pos : pos + 33]))
        pos += 33
    return entries, footer_start


def append(blob: bytes, state: bytes, new_sections) -> bytes:
    """Mirror util::container::append_sections: truncate at the footer,
    append the new payloads, rewrite footer + trailer keeping every old
    entry. Existing payload bytes are never touched."""
    entries, footer_start = parse_entries(blob)
    body = blob[:footer_start]
    table = list(entries)
    for kind, tag, payload in new_sections:
        table.append((kind, tag, len(body), len(payload), fnv1a(payload)))
        body += payload
    footer = footer_body(state, table)
    body += footer
    body += struct.pack("<QQ", len(footer), fnv1a(footer))
    body += FOOTER_MAGIC
    return body


def main():
    out_dir = os.path.join(os.path.dirname(__file__), "..", "rust", "tests", "fixtures")
    os.makedirs(out_dir, exist_ok=True)

    # Section kinds as in api::artifact::binary: 1 = meta,
    # 2 = dense epoch, 3 = quantized epoch.
    dense = container(
        b"dense-state-v1",
        [
            (1, 0, b"meta:dense"),
            (2, 1, pattern(64, 1, 251)),
            (2, 2, pattern(48, 3, 253)),
        ],
    )
    quant = container(
        b"quant-state-v1",
        [
            (1, 0, b"meta:quant"),
            (3, 1, pattern(80, 5, 241)),
            (3, 2, pattern(56, 7, 239)),
        ],
    )
    # A rotated epoch appended to the dense container: the WAL shape the
    # ckmd daemon writes on restart checkpoints.
    appended = append(dense, b"dense-state-v2", [(2, 3, pattern(32, 11, 233))])

    for name, blob in [
        ("dense.ckmc", dense),
        ("quant.ckmc", quant),
        ("appended.ckmc", appended),
    ]:
        path = os.path.join(out_dir, name)
        with open(path, "wb") as f:
            f.write(blob)
        print(f"{name}: {len(blob)} bytes, fnv1a {fnv1a(blob):016x}")


if __name__ == "__main__":
    main()
